//! §Perf — strategy/topology sweep-engine hot path.
//!
//! A sweep is thousands of *small* fluid simulations (one iterate +
//! microbench per point), so its throughput is the product of the fluid
//! engine's event rate and the per-point plan-construction overhead.
//! Budget: the default CLI sweep (t17b, 5×4, all fabrics, 12 strategies)
//! must finish in seconds, and points/s must not regress silently.
//!
//! Run: `cargo bench --bench bench_sweep`

use fred::coordinator::config::FabricKind;
use fred::coordinator::sweep::{factorizations, run_sweep, SweepConfig, WaferDims};
use fred::coordinator::workload;
use fred::util::table::Table;
use std::time::Instant;

fn cfg(
    workloads: Vec<fred::coordinator::workload::Workload>,
    wafers: Vec<WaferDims>,
    fabrics: Vec<FabricKind>,
    max_strategies: usize,
) -> SweepConfig {
    SweepConfig {
        workloads,
        wafers,
        fabrics,
        strategies: None,
        max_strategies,
        bench_bytes: 100e6,
    }
}

fn main() {
    println!("=== §Perf: strategy/topology sweep engine ===");

    // Enumeration is cheap; record it once for the log.
    let t0 = Instant::now();
    let total: usize = (1..=256).map(|n| factorizations(n).len()).sum();
    println!(
        "factorizations(1..=256): {total} strategies in {:.2} ms\n",
        t0.elapsed().as_secs_f64() * 1e3
    );

    let cases: Vec<(&str, SweepConfig)> = vec![
        (
            "resnet152 | 5x4 | all 5 fabrics | 12 strat",
            cfg(
                vec![workload::resnet152()],
                vec![WaferDims::PAPER],
                FabricKind::all().to_vec(),
                12,
            ),
        ),
        (
            "t17b      | 5x4 | all 5 fabrics |  6 strat",
            cfg(
                vec![workload::transformer_17b()],
                vec![WaferDims::PAPER],
                FabricKind::all().to_vec(),
                6,
            ),
        ),
        (
            "resnet152 | 8x8 | mesh + fred-d |  6 strat",
            cfg(
                vec![workload::resnet152()],
                vec![WaferDims { n_l1: 8, per_l1: 8 }],
                vec![FabricKind::Baseline, FabricKind::FredD],
                6,
            ),
        ),
    ];

    let mut table = Table::new(&["sweep", "points", "feasible", "wall", "points/s"]);
    for (name, cfg) in cases {
        let t0 = Instant::now();
        let report = run_sweep(&cfg);
        let dt = t0.elapsed().as_secs_f64();
        let n = report.points.len();
        let feasible = report.points.iter().filter(|p| p.outcome.is_ok()).count();
        table.row(&[
            name.to_string(),
            n.to_string(),
            feasible.to_string(),
            format!("{:.2} s", dt),
            format!("{:.1}", n as f64 / dt),
        ]);
        assert!(feasible > 0, "{name}: no feasible points");
    }
    table.print();
}
