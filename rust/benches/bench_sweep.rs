//! §Perf — strategy/topology sweep-engine hot path.
//!
//! A sweep is thousands of *small* fluid simulations (one iterate +
//! microbench per point), so its throughput is the product of the fluid
//! engine's event rate and the per-point plan-construction overhead.
//! Budget: the default CLI sweep (t17b, 5×4, all fabrics, 12 strategies)
//! must finish in seconds, and points/s must not regress silently.
//!
//! The sweep executor runs points on work-stealing `std::thread::scope`
//! workers (each claims the next spec from a shared atomic index), so
//! the second section compares a forced single-thread run against the
//! auto thread count on the same (multi-wafer) cross-product and asserts
//! the outputs are byte-identical — the determinism contract of
//! `run_sweep`. (Both sides pin `threads` explicitly, which takes
//! precedence over the deprecated `FRED_SWEEP_THREADS` env var — the
//! env is honored only when no explicit count is set.)
//!
//! Run: `cargo bench --bench bench_sweep`

use fred::coordinator::config::FabricKind;
use fred::coordinator::memory::{MemPolicy, Recompute, ZeroStage};
use fred::coordinator::parallelism::WaferSpan;
use fred::coordinator::search::{run_search, SearchAlgo, SearchBudget, SearchConfig};
use fred::coordinator::stagegraph::PipeSchedule;
use fred::coordinator::sweep::{
    factorizations, run_sweep, run_sweep_with, SweepConfig, SweepOptions, WaferDims,
};
use fred::coordinator::timeline::OverlapMode;
use fred::coordinator::workload;
use fred::fabric::egress::EgressTopo;
use fred::runtime::json::Json;
use fred::util::table::Table;
use std::time::Instant;

fn cfg(
    workloads: Vec<fred::coordinator::workload::Workload>,
    wafers: Vec<WaferDims>,
    fabrics: Vec<FabricKind>,
    max_strategies: usize,
) -> SweepConfig {
    SweepConfig {
        workloads,
        wafers,
        fabrics,
        strategies: None,
        max_strategies,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    }
}

fn main() {
    println!("=== §Perf: strategy/topology sweep engine ===");

    // Enumeration is cheap; record it once for the log.
    let t0 = Instant::now();
    let total: usize = (1..=256).map(|n| factorizations(n).len()).sum();
    println!(
        "factorizations(1..=256): {total} strategies in {:.2} ms\n",
        t0.elapsed().as_secs_f64() * 1e3
    );

    let cases: Vec<(&str, SweepConfig)> = vec![
        (
            "resnet152 | 5x4 | all 5 fabrics | 12 strat",
            cfg(
                vec![workload::resnet152()],
                vec![WaferDims::PAPER],
                FabricKind::all().to_vec(),
                12,
            ),
        ),
        (
            "t17b      | 5x4 | all 5 fabrics |  6 strat",
            cfg(
                vec![workload::transformer_17b()],
                vec![WaferDims::PAPER],
                FabricKind::all().to_vec(),
                6,
            ),
        ),
        (
            "resnet152 | 8x8 | mesh + fred-d |  6 strat",
            cfg(
                vec![workload::resnet152()],
                vec![WaferDims { n_l1: 8, per_l1: 8 }],
                vec![FabricKind::Baseline, FabricKind::FredD],
                6,
            ),
        ),
        (
            "gpt3 | 4W x 3 topo x 3 span | fred-d | 6 strat",
            {
                let mut c = cfg(
                    vec![workload::gpt3()],
                    vec![WaferDims::PAPER],
                    vec![FabricKind::FredD],
                    6,
                );
                c.wafer_counts = vec![4];
                c.xwafer_topos = EgressTopo::all().to_vec();
                c.wafer_spans = WaferSpan::all().to_vec();
                c
            },
        ),
        (
            "t17b | 2W x 3 overlap x mb 2,8 | fred-d | 6 strat",
            // The ISSUE 5 axes in isolation: the full-overlap scheduler
            // prices the DP bucket train twice (serial floor + pipelined
            // schedule) and the chunked egress rounds add fluid calls on
            // streaming workloads, so points/s here shows what the
            // timeline engine's overlap modes cost the engine.
            {
                let mut c = cfg(
                    vec![workload::transformer_17b()],
                    vec![WaferDims::PAPER],
                    vec![FabricKind::FredD],
                    6,
                );
                c.wafer_counts = vec![2];
                c.overlaps = OverlapMode::all().to_vec();
                c.microbatches = vec![2, 8];
                c
            },
        ),
        (
            "t17b | 2W(pp) x 4 schedules | fred-d | 6 strat",
            // The ISSUE 6 axis in isolation: 1f1b / interleaved / zb
            // build and schedule the per-microbatch stage graph (O(mb x
            // stages x chunks) phases through the lane scheduler's
            // quadratic selection loop) where gpipe stays closed-form,
            // so points/s here shows what stage-graph pricing costs the
            // engine.
            {
                let mut c = cfg(
                    vec![workload::transformer_17b()],
                    vec![WaferDims::PAPER],
                    vec![FabricKind::FredD],
                    6,
                );
                c.wafer_counts = vec![2];
                c.wafer_spans = vec![WaferSpan::Pp];
                c.schedules = PipeSchedule::all().to_vec();
                c
            },
        ),
        (
            "t17b | 3 zero x 2 recompute x 2 sched | fred-d | 6 strat",
            // The memory axes in isolation: ZeRO stages and recompute
            // multiply the point count 6x but only recompute=full changes
            // pricing (the 4/3 forward re-run), so points/s here shows
            // what the footprint model and the widened cross-product cost
            // the engine under --mem rank.
            {
                let mut c = cfg(
                    vec![workload::transformer_17b()],
                    vec![WaferDims::PAPER],
                    vec![FabricKind::FredD],
                    6,
                );
                c.schedules = vec![PipeSchedule::GPipe, PipeSchedule::OneF1B];
                c.zeros = ZeroStage::all().to_vec();
                c.recomputes = Recompute::all().to_vec();
                c.mem = MemPolicy::Rank;
                c
            },
        ),
        (
            "skew | 1W+4W x all spans x 3 topos | all 5 fabrics | 4 strat",
            // The work-stealing showcase: cheap single-wafer mesh points
            // mixed with fluid-heavy multi-wafer MP-span points in one
            // spec list. A static chunk partition strands the expensive
            // tail on one worker while the rest idle; the claim loop
            // keeps every worker busy, so this case's points/s is the
            // one to watch for executor regressions.
            {
                let mut c = cfg(
                    vec![workload::transformer_17b()],
                    vec![WaferDims::PAPER],
                    FabricKind::all().to_vec(),
                    4,
                );
                c.wafer_counts = vec![1, 4];
                c.xwafer_topos = EgressTopo::all().to_vec();
                c.wafer_spans = vec![
                    WaferSpan::Dp,
                    WaferSpan::Pp,
                    WaferSpan::Mp,
                    WaferSpan::Mixed { pp_wafers: 2, dp_wafers: 2 },
                ];
                c
            },
        ),
        (
            "t17b | 4W x mp + 2x2 span | fred-d | 6 strat",
            // The ISSUE 4 axis in isolation: per-layer egress All-Reduces
            // (MP span) and the two-dimensional mixed span are the most
            // fluid-heavy points of the widened factorization space, so
            // their points/s shows what the new spans cost the engine.
            {
                let mut c = cfg(
                    vec![workload::transformer_17b()],
                    vec![WaferDims::PAPER],
                    vec![FabricKind::FredD],
                    6,
                );
                c.wafer_counts = vec![4];
                c.wafer_spans = vec![
                    WaferSpan::Mp,
                    WaferSpan::Mixed { pp_wafers: 2, dp_wafers: 2 },
                ];
                c
            },
        ),
    ];

    let mut table = Table::new(&["sweep", "points", "feasible", "wall", "points/s"]);
    let mut json_cases: Vec<Json> = Vec::new();
    for (name, cfg) in cases {
        let t0 = Instant::now();
        let report = run_sweep(&cfg);
        let dt = t0.elapsed().as_secs_f64();
        let n = report.points.len();
        let feasible = report.points.iter().filter(|p| p.outcome.is_ok()).count();
        table.row(&[
            name.to_string(),
            n.to_string(),
            feasible.to_string(),
            format!("{:.2} s", dt),
            format!("{:.1}", n as f64 / dt),
        ]);
        json_cases.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("points", Json::Num(n as f64)),
            ("feasible", Json::Num(feasible as f64)),
            ("wall_s", Json::Num(dt)),
            ("points_per_s", Json::Num(n as f64 / dt)),
        ]));
        assert!(feasible > 0, "{name}: no feasible points");
    }
    table.print();

    // ------------------------------------------------ threaded executor
    // The cross-product now includes the egress axes (topology x span),
    // so this doubles as the determinism wall for the link-level egress
    // fabrics: byte-identical output at any thread count must survive
    // ring/tree/dragonfly pricing and PP-across-wafers points.
    println!("\n=== §Perf: threaded sweep executor (multi-wafer + egress axes) ===");
    let mut base = cfg(
        vec![workload::resnet152(), workload::transformer_17b()],
        vec![WaferDims::PAPER],
        FabricKind::all().to_vec(),
        8,
    );
    base.wafer_counts = vec![1, 4, 8];
    base.xwafer_topos = EgressTopo::all().to_vec();
    let mut spans = WaferSpan::all().to_vec();
    // The mixed span applies only to the fleet sizes it factors (4 and 8
    // here via 2x2 / 2x4); the executor skips the rest.
    spans.push(WaferSpan::Mixed { pp_wafers: 2, dp_wafers: 2 });
    spans.push(WaferSpan::Mixed { pp_wafers: 2, dp_wafers: 4 });
    base.wafer_spans = spans;
    // The schedule axes ride the determinism wall too: overlap modes and
    // microbatch overrides must not perturb byte-identity across thread
    // counts.
    base.overlaps = vec![OverlapMode::Off, OverlapMode::Full];
    base.microbatches = vec![4];
    // ... as must the memory axes (footprint annotation + ZeRO sharding).
    base.zeros = vec![ZeroStage::Z0, ZeroStage::Z1];
    base.mem = MemPolicy::Rank;

    let mut seq_cfg = base.clone();
    seq_cfg.threads = 1;
    let t0 = Instant::now();
    let seq = run_sweep(&seq_cfg);
    let dt_seq = t0.elapsed().as_secs_f64();

    let mut par_cfg = base.clone();
    par_cfg.threads = 0; // auto: one worker per core
    let t0 = Instant::now();
    let par = run_sweep(&par_cfg);
    let dt_par = t0.elapsed().as_secs_f64();

    let n = seq.points.len();
    assert_eq!(n, par.points.len());
    assert_eq!(
        seq.to_json().render(),
        par.to_json().render(),
        "threaded sweep must be byte-identical to the sequential run"
    );

    let mut t = Table::new(&["executor", "points", "wall", "points/s"]);
    t.row(&[
        "1 thread".into(),
        n.to_string(),
        format!("{dt_seq:.2} s"),
        format!("{:.1}", n as f64 / dt_seq),
    ]);
    t.row(&[
        "auto threads".into(),
        n.to_string(),
        format!("{dt_par:.2} s"),
        format!("{:.1}", n as f64 / dt_par),
    ]);
    t.print();
    println!(
        "speedup: {:.2}x (outputs byte-identical; both sides pin --threads, which wins over FRED_SWEEP_THREADS)",
        dt_seq / dt_par
    );

    // The executor runs join the throughput record too: the auto-thread
    // row is where a work-distribution regression (e.g. a skewed
    // partition idling workers) shows up even when per-point cost is
    // unchanged.
    let feasible_seq = seq.points.iter().filter(|p| p.outcome.is_ok()).count();
    for (name, wall) in
        [("threaded | 1 thread", dt_seq), ("threaded | auto threads", dt_par)]
    {
        json_cases.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("points", Json::Num(n as f64)),
            ("feasible", Json::Num(feasible_seq as f64)),
            ("wall_s", Json::Num(wall)),
            ("points_per_s", Json::Num(n as f64 / wall)),
        ]));
    }

    // ------------------------------------------------ phase-cache
    // The collective-time table in one number: a fluid-heavy
    // multi-schedule cross-product (stage-graph schedules x ZeRO stages
    // on a 4-wafer PP span) re-prices the same DP/MP/egress phases over
    // and over — ZeRO never changes pricing and the schedules share the
    // per-round collectives — so the memoized run should clear the
    // cold run by >= 1.5x points/s. Byte-identity between the two runs
    // is asserted here too: hits replay the exact f64 the solver would
    // produce, so `--phase-cache off` is a pure de-optimization.
    println!("\n=== §Perf: collective-time table (phase-cache off vs on) ===");
    let mut pc = cfg(
        vec![workload::transformer_17b()],
        vec![WaferDims::PAPER],
        vec![FabricKind::FredD],
        6,
    );
    pc.wafer_counts = vec![4];
    pc.wafer_spans = vec![WaferSpan::Pp];
    pc.schedules = PipeSchedule::all().to_vec();
    pc.zeros = ZeroStage::all().to_vec();
    pc.mem = MemPolicy::Rank;

    let mut cold_cfg = pc.clone();
    cold_cfg.phase_cache = false;
    let t0 = Instant::now();
    let cold = run_sweep_with(&cold_cfg, &mut SweepOptions::default());
    let dt_cold = t0.elapsed().as_secs_f64();

    let mut warm_cfg = pc.clone();
    warm_cfg.phase_cache = true;
    let t0 = Instant::now();
    let warm = run_sweep_with(&warm_cfg, &mut SweepOptions::default());
    let dt_warm = t0.elapsed().as_secs_f64();

    let n_pc = cold.report.points.len();
    assert_eq!(n_pc, warm.report.points.len());
    assert_eq!(
        cold.report.to_json().render(),
        warm.report.to_json().render(),
        "phase-cache on must be byte-identical to off"
    );
    assert!(cold.stats.phase.is_none(), "cold run must not build a table");
    let phase = warm.stats.phase.expect("warm run records phase-cache stats");
    let hit_rate = phase.hit_rate();

    let mut pt = Table::new(&["phase cache", "points", "wall", "points/s", "hit rate"]);
    pt.row(&[
        "off (cold)".into(),
        n_pc.to_string(),
        format!("{dt_cold:.2} s"),
        format!("{:.1}", n_pc as f64 / dt_cold),
        "-".into(),
    ]);
    pt.row(&[
        "on (warm)".into(),
        n_pc.to_string(),
        format!("{dt_warm:.2} s"),
        format!("{:.1}", n_pc as f64 / dt_warm),
        format!("{:.1}%", hit_rate * 100.0),
    ]);
    pt.print();
    println!(
        "phase-cache speedup: {:.2}x ({} hits / {} misses)",
        dt_cold / dt_warm,
        phase.total_hits(),
        phase.total_misses()
    );

    let feasible_pc = cold.report.points.iter().filter(|p| p.outcome.is_ok()).count();
    json_cases.push(Json::obj(vec![
        ("name", Json::Str("phase-cache | cold (off)".to_string())),
        ("points", Json::Num(n_pc as f64)),
        ("feasible", Json::Num(feasible_pc as f64)),
        ("wall_s", Json::Num(dt_cold)),
        ("points_per_s", Json::Num(n_pc as f64 / dt_cold)),
    ]));
    json_cases.push(Json::obj(vec![
        ("name", Json::Str("phase-cache | warm (on)".to_string())),
        ("points", Json::Num(n_pc as f64)),
        ("feasible", Json::Num(feasible_pc as f64)),
        ("wall_s", Json::Num(dt_warm)),
        ("points_per_s", Json::Num(n_pc as f64 / dt_warm)),
        ("phase_hit_rate", Json::Num(hit_rate)),
        ("phase_hits", Json::Num(phase.total_hits() as f64)),
        ("phase_misses", Json::Num(phase.total_misses() as f64)),
    ]));
    assert!(
        phase.total_hits() > 0,
        "multi-schedule sweep must hit the collective-time table"
    );

    // ---------------------------------------------- search efficiency
    // The optimizer's value proposition in one number: how many points
    // it prices before landing on its best (vs the space the exhaustive
    // sweep must pay for). Both algorithms walk the same spec list and
    // price through the same evaluator, so points/s is comparable with
    // the sweep rows; `priced_to_best` is the efficiency headline.
    println!("\n=== §Perf: optimizer-driven search vs exhaustive sweep ===");
    let mut space_cfg = cfg(
        vec![workload::resnet152(), workload::transformer_17b()],
        vec![WaferDims::PAPER],
        vec![FabricKind::FredA, FabricKind::FredD],
        8,
    );
    space_cfg.schedules = vec![PipeSchedule::GPipe, PipeSchedule::OneF1B];
    space_cfg.zeros = ZeroStage::all().to_vec();

    let t0 = Instant::now();
    let exhaustive = run_sweep(&space_cfg);
    let dt_sweep = t0.elapsed().as_secs_f64();
    let space = exhaustive.points.len();
    let argmin = exhaustive.points[0].outcome.as_ref().ok().map(|m| m.per_sample);

    let mut st =
        Table::new(&["explorer", "space", "priced", "to best", "wall", "points/s", "argmin?"]);
    st.row(&[
        "exhaustive sweep".into(),
        space.to_string(),
        space.to_string(),
        "-".into(),
        format!("{dt_sweep:.2} s"),
        format!("{:.1}", space as f64 / dt_sweep),
        "yes".into(),
    ]);
    for (label, algo) in [("anneal", SearchAlgo::Anneal), ("evolve", SearchAlgo::Evolve)] {
        let scfg = SearchConfig {
            algo,
            seed: 1,
            budget: SearchBudget::Points(space / 4),
            ..SearchConfig::default()
        };
        let t0 = Instant::now();
        let result = run_search(&space_cfg, &scfg);
        let dt = t0.elapsed().as_secs_f64();
        let to_best = result.trajectory.last().map(|s| s.priced).unwrap_or(0);
        let best = result.best().and_then(|p| p.outcome.as_ref().ok()).map(|m| m.per_sample);
        let hit = best.is_some() && best == argmin;
        st.row(&[
            format!("search | {label} | 25% budget"),
            space.to_string(),
            result.priced.to_string(),
            to_best.to_string(),
            format!("{dt:.2} s"),
            format!("{:.1}", result.priced as f64 / dt),
            if hit { "yes" } else { "no" }.into(),
        ]);
        let feasible = result.report.points.iter().filter(|p| p.outcome.is_ok()).count();
        json_cases.push(Json::obj(vec![
            ("name", Json::Str(format!("search | {label} | 25% budget"))),
            ("points", Json::Num(result.priced as f64)),
            ("feasible", Json::Num(feasible as f64)),
            ("wall_s", Json::Num(dt)),
            ("points_per_s", Json::Num(result.priced as f64 / dt)),
            ("space", Json::Num(space as f64)),
            ("priced_to_best", Json::Num(to_best as f64)),
            ("found_argmin", Json::Bool(hit)),
        ]));
        assert!(result.priced <= space / 4, "{label}: budget overrun");
    }
    st.print();

    // Machine-readable throughput record for regression tracking: one
    // entry per case, points/s being the headline number. Written to the
    // repo root (not the bench's cwd) so ci.sh and the committed
    // baseline always agree on the path.
    let bench_doc = Json::obj(vec![
        ("bench", Json::Str("sweep".to_string())),
        ("cases", Json::Arr(json_cases)),
    ]);
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sweep.json");
    match std::fs::write(bench_path, format!("{}\n", bench_doc.render())) {
        Ok(()) => println!("(wrote {bench_path})"),
        Err(e) => eprintln!("(cannot write {bench_path}: {e})"),
    }
}
