//! Fig. 2 — normalized comp/comm overhead of Transformer-17B
//! parallelization strategies on the baseline 2D mesh.
//!
//! The paper's figure is per-sample (throughput view): with minibatch =
//! DP×16, per-sample compute is strategy-invariant while the comm terms
//! vary; compute-efficient strategies (MP-heavy) can lose end-to-end —
//! MP(20) worse than MP(5)-DP(4) is the paper's headline observation.
//!
//! Run: `cargo bench --bench bench_fig2`

use fred::coordinator::config::FabricKind;
use fred::coordinator::metrics::CommType;
use fred::coordinator::parallelism::Strategy;
use fred::coordinator::sim::Simulator;
use fred::coordinator::workload;
use fred::util::table::Table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let w = workload::transformer_17b();
    let strategies = [
        Strategy::new(20, 1, 1),
        Strategy::new(5, 4, 1),
        Strategy::new(4, 5, 1),
        Strategy::new(2, 5, 2),
        Strategy::new(5, 2, 2),
        Strategy::new(1, 20, 1),
    ];
    println!("=== Fig. 2: Transformer-17B strategies on 2D-Mesh (per-sample) ===");
    let mut table = Table::new(&[
        "strategy", "comp", "MP", "DP", "PP", "total", "norm(vs MP(5)-DP(4))",
    ]);
    // Normalize to MP(5)-DP(4)-PP(1), the strategy the paper contrasts
    // MP(20) against.
    let mut rows = Vec::new();
    for s in strategies {
        let sim = Simulator::new(FabricKind::Baseline, w.clone(), s);
        let b = sim.iterate();
        let per_sample = 1.0 / w.minibatch(&s) as f64;
        rows.push((s, b, per_sample));
    }
    let norm = {
        let (_, b, k) = &rows[1];
        b.total() * k
    };
    for (s, b, k) in &rows {
        table.row(&[
            s.to_string(),
            format!("{:.3}", b.compute * k / norm),
            format!("{:.3}", b.get(CommType::Mp) * k / norm),
            format!("{:.3}", b.get(CommType::Dp) * k / norm),
            format!("{:.3}", b.get(CommType::Pp) * k / norm),
            format!("{:.3}", b.total() * k / norm),
            format!("{:.2}", b.total() * k / norm),
        ]);
    }
    table.print();
    let mp20 = rows[0].1.total() * rows[0].2;
    let mp5dp4 = rows[1].1.total() * rows[1].2;
    println!(
        "\npaper's claim (Sec. I): MP(20) total > MP(5)-DP(4) total per sample: {} ({:.2}x)",
        mp20 > mp5dp4,
        mp20 / mp5dp4
    );
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
