//! §Perf — fluid-flow simulator throughput.
//!
//! The fluid simulator is the inner loop of every experiment (Figs. 2, 9,
//! 10 all run thousands of plans). DESIGN.md §8 budgets ≥1M
//! transfer-events/s and the full Fig. 10 suite <30 s.
//!
//! Run: `cargo bench --bench bench_fluidsim`

use fred::coordinator::config::FabricKind;
use fred::coordinator::sim::Simulator;
use fred::coordinator::workload;
use fred::fabric::fluid::{FluidSim, Network, Transfer};
use fred::util::prng::Xorshift64;
use fred::util::table::Table;
use std::time::Instant;

fn main() {
    println!("=== §Perf: fluid simulator ===");

    // Raw engine: random transfer sets on a 200-link network.
    let mut net = Network::new();
    let links: Vec<_> = (0..200).map(|i| net.add_link(format!("l{i}"), 1e12)).collect();
    let sim = FluidSim::new(net);
    let mut rng = Xorshift64::new(1);
    let mut table = Table::new(&["transfers", "runs", "events/s", "per-run"]);
    for n_transfers in [10usize, 100, 400] {
        let sets: Vec<Vec<Transfer>> = (0..50)
            .map(|_| {
                (0..n_transfers)
                    .map(|i| {
                        let n_links = rng.range(1, 6);
                        let ls: Vec<_> = (0..n_links)
                            .map(|_| links[rng.range(0, links.len())])
                            .collect();
                        Transfer::new(ls, 1e9 + rng.next_f64() * 1e10, i)
                    })
                    .collect()
            })
            .collect();
        let t0 = Instant::now();
        let mut total_events = 0usize;
        for set in &sets {
            let r = sim.run(set);
            total_events += r.transfer_done.len();
        }
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[
            n_transfers.to_string(),
            sets.len().to_string(),
            format!("{:.2}M", total_events as f64 / dt / 1e6),
            format!("{:.1} us", dt / sets.len() as f64 * 1e6),
        ]);
    }
    table.print();

    // End-to-end: the full Fig. 10 suite wall time.
    let t0 = Instant::now();
    let mut total = 0.0;
    for w in workload::Workload::all() {
        for kind in [FabricKind::Baseline, FabricKind::FredC, FabricKind::FredD] {
            let s = Simulator::new(kind, w.clone(), w.default_strategy);
            total += s.iterate().total();
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nfull Fig. 10 suite (12 simulations): {:.2}s wall (budget 30s), sim-total {total:.2}s",
        dt
    );
    assert!(total > 0.0);
}
