//! Fig. 10 — end-to-end training-time breakdowns, Baseline vs FRED-C vs
//! FRED-D, for the four Table V workloads, normalized to the baseline.
//!
//! Paper speedups: ResNet-152 1.41/1.76×, Transformer-17B 1.75/1.87×,
//! GPT-3 1.34/1.34×, Transformer-1T 1.4/1.4×.
//!
//! Run: `cargo bench --bench bench_fig10`

use fred::coordinator::config::FabricKind;
use fred::coordinator::metrics::CommType;
use fred::coordinator::sim::Simulator;
use fred::coordinator::workload::Workload;
use fred::util::table::Table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let paper: &[(&str, f64, f64)] = &[
        ("ResNet-152", 1.41, 1.76),
        ("Transformer-17B", 1.75, 1.87),
        ("GPT-3", 1.34, 1.34),
        ("Transformer-1T", 1.40, 1.40),
    ];
    println!("=== Fig. 10: end-to-end training time (normalized to baseline) ===\n");
    let mut summary = Table::new(&[
        "workload", "FRED-C meas", "FRED-C paper", "FRED-D meas", "FRED-D paper",
    ]);
    for w in Workload::all() {
        let strategy = w.default_strategy;
        println!("{} | {} | {:?}", w.name, strategy, w.exec_mode);
        let mut table = Table::new(&[
            "fabric", "comp", "input_load", "MP", "DP", "PP", "stream", "total", "speedup",
        ]);
        let mut base = None;
        let mut meas = (0.0, 0.0);
        for kind in [FabricKind::Baseline, FabricKind::FredC, FabricKind::FredD] {
            let sim = Simulator::new(kind, w.clone(), strategy);
            let b = sim.iterate();
            let norm = *base.get_or_insert(b.total());
            let sp = norm / b.total();
            match kind {
                FabricKind::FredC => meas.0 = sp,
                FabricKind::FredD => meas.1 = sp,
                _ => {}
            }
            table.row(&[
                kind.name().to_string(),
                format!("{:.3}", b.compute / norm),
                format!("{:.3}", b.get(CommType::InputLoad) / norm),
                format!("{:.3}", b.get(CommType::Mp) / norm),
                format!("{:.3}", b.get(CommType::Dp) / norm),
                format!("{:.3}", b.get(CommType::Pp) / norm),
                format!("{:.3}", b.get(CommType::Stream) / norm),
                format!("{:.3}", b.total() / norm),
                format!("{sp:.2}x"),
            ]);
        }
        table.print();
        println!();
        let p = paper.iter().find(|(n, _, _)| *n == w.name).unwrap();
        summary.row(&[
            w.name.clone(),
            format!("{:.2}x", meas.0),
            format!("{:.2}x", p.1),
            format!("{:.2}x", meas.1),
            format!("{:.2}x", p.2),
        ]);
    }
    println!("=== summary: measured vs paper ===");
    summary.print();
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
