//! Fig. 4 — broadcast channel load on the 2D mesh and the resulting I/O
//! derating.
//!
//! (a) the per-link stream counts of the side-oriented broadcast trees;
//! (b) the (2N−1)·P hotspot; measured line-rate factor from the fluid
//! simulator vs the paper's closed form.
//!
//! Run: `cargo bench --bench bench_fig4`

use fred::fabric::mesh::Mesh2D;
use fred::fabric::topology::{Fabric, IoDirection};
use fred::util::table::Table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("=== Fig. 4: mesh I/O channel-load analysis ===");
    let mut table = Table::new(&[
        "mesh", "io ch", "hotspot load", "(2N-1)", "factor (fluid)", "factor (paper formula)",
    ]);
    for (rows, cols) in [(4usize, 4usize), (5, 4), (6, 6), (8, 8)] {
        let m = Mesh2D::new(rows, cols, 750e9, 128e9, 20e-9);
        let (max_load, _) = m.channel_load_analysis();
        // Measured: stream 1 s worth of full line-rate traffic.
        let all: Vec<usize> = (0..rows * cols).collect();
        let total = m.io_count() as f64 * 128e9;
        let t = m.run_plan(&m.plan_io_stream(IoDirection::Broadcast, total, &all));
        let paper = (750.0 / ((2 * rows - 1) as f64 * 128.0)).min(1.0);
        table.row(&[
            format!("{rows}x{cols}"),
            m.io_count().to_string(),
            max_load.to_string(),
            (2 * rows - 1).to_string(),
            format!("{:.3}", 1.0 / t),
            format!("{paper:.3}"),
        ]);
    }
    table.print();
    println!("\npaper: 4x4 hotspot = 7P; 5-row baseline derates GPT-3 I/O to 750/1152 = 0.65");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
