//! §Perf — FRED routing hot path.
//!
//! The routing algorithm runs at compile time in the paper (results are
//! stored in the switch control units), but it sits on the coordinator's
//! planning path here, so DESIGN.md §8 budgets ≤10 µs per routing call at
//! wafer port counts. Measures route_flows across port counts, flow
//! counts, and the conflict-resolution paths.
//!
//! Run: `cargo bench --bench bench_routing`

use fred::fabric::fred::{route_flows, routing, Flow};
use fred::util::prng::Xorshift64;
use fred::util::table::Table;
use std::time::Instant;

fn random_flows(rng: &mut Xorshift64, ports: usize, n_flows: usize) -> Vec<Flow> {
    // Disjoint port groups => always well-formed.
    let mut perm: Vec<usize> = (0..ports).collect();
    rng.shuffle(&mut perm);
    let size = (ports / n_flows).max(2);
    perm.chunks(size)
        .take(n_flows)
        .filter(|c| c.len() >= 2)
        .map(|c| Flow::all_reduce(c.to_vec()))
        .collect()
}

fn bench<F: FnMut() -> bool>(iters: usize, mut f: F) -> (f64, usize) {
    let t0 = Instant::now();
    let mut ok = 0usize;
    for _ in 0..iters {
        if f() {
            ok += 1;
        }
    }
    (t0.elapsed().as_secs_f64() / iters as f64, ok)
}

fn main() {
    println!("=== §Perf: FRED conflict-graph routing ===");
    let mut table = Table::new(&["case", "per-call", "routed", "budget"]);
    let cases: Vec<(String, usize, usize, usize)> = vec![
        ("FRED3(12), 2 flows".into(), 12, 3, 2),
        ("FRED3(12), 4 flows".into(), 12, 3, 4),
        ("FRED3(12), 6 flows".into(), 12, 3, 6),
        ("FRED3(32), 8 flows".into(), 32, 3, 8),
        ("FRED3(64), 16 flows".into(), 64, 3, 16),
        ("FRED2(64), 16 flows".into(), 64, 2, 16),
    ];
    for (name, ports, m, n_flows) in cases {
        let mut rng = Xorshift64::new(42);
        let iters = 2000;
        let (per_call, ok) = bench(iters, || {
            let flows = random_flows(&mut rng, ports, n_flows);
            route_flows(ports, m, &flows).is_ok()
        });
        table.row(&[
            name,
            format!("{:.2} us", per_call * 1e6),
            format!("{}/{}", ok, iters),
            if per_call < 10e-6 { "<=10us OK".into() } else { "OVER".to_string() },
        ]);
    }
    table.print();

    // Conflict-resolution strategies on the Fig. 7(j) set.
    let fig7j = vec![
        Flow::all_reduce(vec![1, 2]),
        Flow::all_reduce(vec![3, 4]),
        Flow::all_reduce(vec![5, 0]),
        Flow::all_reduce(vec![6, 7]),
    ];
    println!("\nconflict resolution on the Fig. 7(j) set (FRED_2(8)):");
    let t0 = Instant::now();
    let rounds = routing::route_with_blocking(8, 2, &fig7j);
    println!(
        "  (1) blocking: {} rounds in {:.1} us",
        rounds.len(),
        t0.elapsed().as_secs_f64() * 1e6
    );
    let t0 = Instant::now();
    let m = routing::min_m_for(8, 2, &fig7j, 4);
    println!(
        "  (2) raise m: m={:?} in {:.1} us",
        m,
        t0.elapsed().as_secs_f64() * 1e6
    );
    let t0 = Instant::now();
    let steps = routing::decompose_to_unicast_ring(&fig7j[0]);
    println!(
        "  (3) unicast decomposition: {} serial steps in {:.1} us",
        steps.len(),
        t0.elapsed().as_secs_f64() * 1e6
    );
}
