"""L2: JAX transformer LM — the per-NPU compute graph of the training stack.

This is the build-time half of the paper's "weight stationary" NPU: a
decoder-only transformer whose big GEMMs go through the L1 Pallas kernel
(`kernels.block_matmul.matmul`). `aot.py` lowers the entry points below to
HLO text once; the Rust coordinator then executes them via PJRT on every
training step — python is never on the request path.

Entry points (all functional, all fixed-shape, all f32 except tokens):

* ``grad_step(params, tokens) -> (loss, grads)`` — per-worker fwd+bwd.
  The DP trainer calls this on every simulated worker, then reduces the
  gradient buckets through the FRED fabric (in-network flow_reduce).
* ``adamw_update(params, grads, m, v, step) -> (params, m, v)`` — the
  optimizer, applied after reduction.
* ``train_step(params, m, v, step, tokens) -> (loss, params, m, v)`` —
  fused single-worker step (quickstart / compute-time calibration).

Parameters are a nested dict; the flatten order (jax tree order = sorted
dict keys) is recorded in ``artifacts/manifest.json`` so Rust passes
literals in the right positions.
"""

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.block_matmul import matmul as pallas_matmul
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters (defaults: the fast CPU e2e config)."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    seq_len: int = 128
    batch: int = 8  # per-worker microbatch
    use_pallas: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 4 * d * d + 2 * d * f + 4 * d  # attn + ffn + 2 LN
        return self.n_layers * per_layer + 2 * v * d + self.seq_len * d + 2 * d

    def flops_per_token_fwd(self) -> float:
        """Dense fwd FLOPs/token (2*params matmul convention + attention)."""
        d = self.d_model
        per_layer = 2 * (4 * d * d + 2 * d * self.d_ff) + 4 * self.seq_len * d
        return self.n_layers * per_layer + 2 * 2 * self.vocab * d


# Canonical "large" config (~100M params) for the --large e2e run.
LARGE = ModelConfig(vocab=32768, d_model=768, n_layers=12, n_heads=12,
                    d_ff=3072, seq_len=256, batch=4)


def _mm(cfg: ModelConfig, x, w):
    """2-D matmul through the Pallas kernel (or jnp fallback)."""
    if cfg.use_pallas:
        return pallas_matmul(x, w)
    return kref.matmul_ref(x, w)


def _dense(cfg: ModelConfig, x, w):
    """[..., d_in] @ [d_in, d_out] with the leading dims flattened so the
    Pallas kernel always sees a 2-D GEMM (the MXU-tiled hot path)."""
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = _mm(cfg, x2, w)
    return y.reshape(lead + (w.shape[-1],))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Initialize parameters (scaled-normal init, fp32)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 6 * cfg.n_layers + 4))
    d, f = cfg.d_model, cfg.d_ff

    def norm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    params: Dict[str, Any] = {
        "embed": norm(next(keys), (cfg.vocab, d), 0.02),
        "pos_embed": norm(next(keys), (cfg.seq_len, d), 0.02),
        "unembed": norm(next(keys), (d, cfg.vocab), d ** -0.5),
        "final_ln": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        "layers": {},
    }
    for i in range(cfg.n_layers):
        params["layers"][f"{i:02d}"] = {
            "wq": norm(next(keys), (d, d), d ** -0.5),
            "wk": norm(next(keys), (d, d), d ** -0.5),
            "wv": norm(next(keys), (d, d), d ** -0.5),
            "wo": norm(next(keys), (d, d), (2 * d * cfg.n_layers) ** -0.5),
            "w1": norm(next(keys), (d, f), d ** -0.5),
            "w2": norm(next(keys), (f, d), (2 * f * cfg.n_layers) ** -0.5),
            "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        }
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(cfg: ModelConfig, lp, x):
    """Causal multi-head self-attention; QKV/O projections are Pallas
    GEMMs, the per-head score/value contractions stay in jnp (small,
    bandwidth-bound — not the MXU hot-spot)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = _dense(cfg, x, lp["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = _dense(cfg, x, lp["wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = _dense(cfg, x, lp["wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return _dense(cfg, out, lp["wo"])


def _block(cfg: ModelConfig, lp, x):
    x = x + _attention(cfg, lp, _layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"]))
    h = _dense(cfg, _layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"]), lp["w1"])
    h = jax.nn.gelu(h)
    return x + _dense(cfg, h, lp["w2"])


def forward(cfg: ModelConfig, params, tokens):
    """``tokens [B, S] (i32) -> logits [B, S, vocab]``."""
    x = params["embed"][tokens] + params["pos_embed"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        x = _block(cfg, params["layers"][f"{i:02d}"], x)
    x = _layer_norm(x, params["final_ln"]["scale"], params["final_ln"]["bias"])
    return _dense(cfg, x, params["unembed"])


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token cross-entropy over ``tokens [B, S+1]``."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def grad_step(cfg: ModelConfig, params, tokens):
    """Per-worker fwd+bwd: ``-> (loss, grads)`` (grads same tree as params)."""
    return jax.value_and_grad(functools.partial(loss_fn, cfg))(params, tokens)


def adamw_update(
    params, grads, m, v, step,
    lr=3e-4, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
) -> Tuple[Any, Any, Any]:
    """AdamW. ``step`` is a float scalar (1-based). Returns (params, m, v)."""

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return p2, m2, v2

    flat = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m, new_v


def zeros_like_tree(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def train_step(cfg: ModelConfig, params, m, v, step, tokens):
    """Fused single-worker step: ``-> (loss, params, m, v)``."""
    loss, grads = grad_step(cfg, params, tokens)
    params, m, v = adamw_update(params, grads, m, v, step)
    return loss, params, m, v


def param_leaves(params):
    """Flattened (path, leaf) pairs in jax tree order — the argument order
    contract with the Rust runtime (recorded in the manifest)."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        out.append((name, leaf))
    return out
