"""L1 Pallas kernel: MXU-shaped blocked matmul — the NPU compute hot-spot.

The paper's NPUs spend their time between communication phases on dense
layer compute (Table II: 1000 TFLOPS fp16). The hot-spot is the matmul; we
express it as a Pallas kernel so the same code object is (a) the unit the
L2 model lowers into its HLO, and (b) the thing whose VMEM/MXU structure we
reason about for the perf contract.

Hardware adaptation (GPU paper -> TPU kernel, DESIGN.md §Hardware-
Adaptation): instead of threadblock tiles + shared memory we use
``BlockSpec`` tiles sized to the MXU systolic array — 128x128 output tiles
with a K-striding grid axis, fp32 accumulation in the output ref. The grid
order (k innermost) makes the accumulation a legal revisiting schedule and
lets Pallas double-buffer the HBM->VMEM streams of the x/w tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against `ref.matmul_ref` by pytest.

The kernel is wrapped in ``jax.custom_vjp`` so `model.py` can call it under
``jax.grad``: the backward pass is two more calls of the same kernel
(dx = g @ w^T, dw = x^T @ g), which mirrors how fwd and bwd GEMMs hit the
same MXU path on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles (multiples of the 128x128 systolic array).
# §Perf iteration (EXPERIMENTS.md): 256-cubed tiles keep the per-step VMEM
# footprint at 1.3 MB (within the 4 MB budget) while quartering the grid
# step count — 4.0x faster under interpret=True (4.45 -> 1.12 s/grad_step)
# and fewer HBM<->VMEM round-trips on real hardware. 512 would be ~1.3x
# faster still but blows the VMEM budget (5.2 MB).
BM, BN, BK = 256, 256, 256


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid step (i, j, k): o[i,j] += x[i,k] @ w[k,j], fp32 accumulate."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(
        x, w, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_raw(x, w, bm, bn, bk):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)

    def pad2(a, b0, b1):
        p0 = (-a.shape[0]) % b0
        p1 = (-a.shape[1]) % b1
        if p0 or p1:
            a = jnp.pad(a, ((0, p0), (0, p1)))
        return a

    xp = pad2(x, bm, bk)
    wp = pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(xp, wp)
    return out[:m, :n].astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, bm=BM, bn=BN, bk=BK):
    """``x @ w`` through the Pallas kernel, differentiable.

    Shapes need not be tile multiples (the wrapper pads — static under
    jit). Accumulation is fp32; output dtype follows ``x``.
    """
    return _matmul_raw(x, w, bm, bn, bk)


def _matmul_fwd(x, w, bm, bn, bk):
    return _matmul_raw(x, w, bm, bn, bk), (x, w)


def _matmul_bwd(bm, bn, bk, res, g):
    x, w = res
    dx = _matmul_raw(g, w.T, bm, bn, bk)
    dw = _matmul_raw(x.T, g, bm, bn, bk)
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def mxu_utilization_estimate(m: int, n: int, k: int,
                             bm: int = BM, bn: int = BN, bk: int = BK) -> float:
    """Fraction of MXU issue slots doing useful work = real FLOPs over
    padded-tile FLOPs. 1.0 when every dim divides its tile."""
    ceil = lambda a, b: -(-a // b)
    padded = (ceil(m, bm) * bm) * (ceil(n, bn) * bn) * (ceil(k, bk) * bk)
    return (m * n * k) / padded


def vmem_footprint_bytes(bm: int = BM, bn: int = BN, bk: int = BK,
                         dtype_bytes: int = 4) -> int:
    """VMEM bytes live per grid step: x tile + w tile + fp32 out tile,
    x2 for double buffering of the streamed inputs."""
    return 2 * (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
