"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: pytest (and the hypothesis sweeps)
assert that every Pallas kernel matches the corresponding function here to
within dtype tolerance. They are also used by `model.py` as the
`use_pallas=False` fallback path so the model itself can be tested without
Pallas in the loop.
"""

import jax.numpy as jnp


def flow_reduce_ref(x, op="sum"):
    """Reference for the FRED flow (reduce-broadcast) kernel.

    ``x`` is ``[P, N]`` — one row per switch input port. The result is the
    reduction across ports broadcast back to every output port, i.e. the
    mathematical effect of an in-network All-Reduce flow with
    ``IPs = OPs = {0..P-1}`` (paper Sec. V-A).

    Reduction is performed in fp32 regardless of input dtype, mirroring the
    R-muSwitch adder datapath, then cast back.
    """
    acc = jnp.sum(x.astype(jnp.float32), axis=0, keepdims=True)
    if op == "mean":
        acc = acc / x.shape[0]
    elif op != "sum":
        raise ValueError(f"unknown op {op!r}")
    return jnp.broadcast_to(acc, x.shape).astype(x.dtype)


def reduce_ref(x, op="sum"):
    """Reference for a Reduce flow (|OPs| = 1): ``[P, N] -> [N]``."""
    acc = jnp.sum(x.astype(jnp.float32), axis=0)
    if op == "mean":
        acc = acc / x.shape[0]
    elif op != "sum":
        raise ValueError(f"unknown op {op!r}")
    return acc.astype(x.dtype)


def matmul_ref(x, w):
    """Reference for the blocked matmul kernel: fp32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)
