"""L1 Pallas kernel: the FRED flow (reduce-then-broadcast) dataflow.

A FRED *flow* (paper Sec. V-A) reduces the data arriving on a set of input
ports and broadcasts the result to a set of output ports; the R-/D-/RD-
muSwitches implement it as a tree of 2x2 reduce/broadcast elements inside
the switch. As a kernel the same dataflow is: stack the P port buffers into
``[P, N]``, tree-reduce across the port axis in fp32 (the adder datapath),
broadcast back to all ports.

Hardware adaptation (paper targets a wafer of GPU-like NPUs; we think in
TPU/Pallas terms per DESIGN.md §Hardware-Adaptation): the port axis stays
resident while the element axis is tiled through VMEM via the grid —
``BlockSpec((P, block_n), lambda i: (0, i))`` expresses the HBM->VMEM
streaming schedule that the switch realizes with per-port SRAM buffers
(24 KB/port in Table III). Reduction across P is a vectorized column sum
(VPU work, no MXU involvement), matching the switch's adder trees.

Pallas is always invoked with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md), and
the correctness contract is checked against `ref.py` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default element-axis tile. With P <= 32 ports and fp32 the live block
# (P * BLOCK_N * 4 B * 2 buffers) stays within a 4 MB VMEM budget:
# 32 * 32768 * 4 * 2 = 8 MB at P=32 but 1.05 MB at the wafer's P=4 DP
# width. §Perf iteration (EXPERIMENTS.md): 2048 -> 32768 cut the grid step
# count 16x and the interpret-mode reduction from 123 ms to 12.6 ms per
# 1 MB bucket (the wrapper clamps block_n to N, so small inputs are
# unaffected); larger tiles (131072) exceed the VMEM budget at P >= 8.
DEFAULT_BLOCK_N = 32768


def _flow_reduce_kernel(x_ref, o_ref, *, mean: bool):
    """One grid step: reduce a [P, bn] tile across ports, broadcast back."""
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.sum(x, axis=0, keepdims=True)
    if mean:
        acc = acc / x.shape[0]
    o_ref[...] = jnp.broadcast_to(acc, o_ref.shape).astype(o_ref.dtype)


def _reduce_kernel(x_ref, o_ref, *, mean: bool):
    """Reduce-only variant (|OPs| = 1): [P, bn] tile -> [bn]."""
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.sum(x, axis=0)
    if mean:
        acc = acc / x.shape[0]
    o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to_multiple(x, block_n):
    n = x.shape[-1]
    rem = n % block_n
    if rem == 0:
        return x, n
    pad = block_n - rem
    return jnp.pad(x, ((0, 0), (0, pad))), n


def auto_block_n(p: int, budget_bytes: int = 4 << 20) -> int:
    """Largest power-of-two tile keeping 2*p*block_n*4 B within the VMEM
    budget, clamped to [2048, DEFAULT_BLOCK_N]."""
    cap = max(budget_bytes // (2 * 4 * max(p, 1)), 2048)
    bn = 2048
    while bn * 2 <= min(cap, DEFAULT_BLOCK_N):
        bn *= 2
    return bn


@functools.partial(jax.jit, static_argnames=("op", "block_n"))
def flow_reduce(x, op="sum", block_n=None):
    """All-Reduce flow: ``[P, N] -> [P, N]`` (IPs = OPs = all ports).

    ``op`` is "sum" or "mean" ("mean" is what the data-parallel trainer
    wants for gradient averaging). ``N`` need not divide ``block_n``; the
    wrapper pads (shapes are static under jit, so the padding is free of
    dynamism).
    """
    if op not in ("sum", "mean"):
        raise ValueError(f"unknown op {op!r}")
    p, n = x.shape
    bn = min(block_n or auto_block_n(p), max(n, 1))
    xp, orig_n = _pad_to_multiple(x, bn)
    grid = (xp.shape[1] // bn,)
    out = pl.pallas_call(
        functools.partial(_flow_reduce_kernel, mean=(op == "mean")),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((p, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((p, bn), lambda i: (0, i)),
        interpret=True,
    )(xp)
    return out[:, :orig_n]


@functools.partial(jax.jit, static_argnames=("op", "block_n"))
def reduce_flow(x, op="sum", block_n=None):
    """Reduce flow: ``[P, N] -> [N]`` (|OPs| = 1), e.g. gradient
    reduction toward an I/O controller in weight-streaming mode."""
    if op not in ("sum", "mean"):
        raise ValueError(f"unknown op {op!r}")
    p, n = x.shape
    bn = min(block_n or auto_block_n(p), max(n, 1))
    xp, orig_n = _pad_to_multiple(x, bn)
    grid = (xp.shape[1] // bn,)
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, mean=(op == "mean")),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1],), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((p, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        interpret=True,
    )(xp)
    return out[:orig_n]


def vmem_footprint_bytes(p: int, block_n: int = None,
                         dtype_bytes: int = 4) -> int:
    """Analytical VMEM-resident bytes for one grid step (in + out tiles).

    Used by DESIGN.md §Perf / EXPERIMENTS.md §Perf — interpret-mode
    wallclock is not a TPU proxy, so the perf contract on L1 is structural.
    """
    return 2 * p * (block_n or auto_block_n(p)) * dtype_bytes
