"""AOT compiler: lower the L2 entry points to HLO **text** + a manifest.

This is the one place python runs — at build time (`make artifacts`). It
lowers each entry point with fixed example shapes and writes:

* ``artifacts/<name>.hlo.txt`` — HLO text (NOT a serialized
  HloModuleProto: jax >= 0.5 emits 64-bit instruction ids that the xla
  crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
  round-trips cleanly — see /opt/xla-example/README.md).
* ``artifacts/manifest.json`` — the argument-order contract with the Rust
  runtime: flattened parameter names/shapes/dtypes, per-artifact
  input/output signatures, model hyper-parameters, trainer constants.

Usage::

    python -m compile.aot --out-dir ../artifacts [--config small|large]
                          [--dp 4] [--bucket 262144] [--steps-check]
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.flow_reduce import flow_reduce


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_str(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "bfloat16": "bf16", "float16": "f16"}[str(x.dtype)]


def _sig(tree):
    """Flatten a pytree of arrays into the manifest signature list, in jax
    tree order — the exact order of XLA computation parameters."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        out.append({"name": name or "arg",
                    "shape": list(leaf.shape),
                    "dtype": _dtype_str(leaf)})
    return out


def lower_artifact(fn, example_args, name, out_dir, manifest):
    """Lower ``fn(*example_args)`` and record it in the manifest."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *example_args)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "inputs": _sig(example_args),
        "outputs": _sig(out_shapes),
    }
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO, "
          f"{len(manifest['artifacts'][name]['inputs'])} inputs, "
          f"{len(manifest['artifacts'][name]['outputs'])} outputs")
    return path


def build(out_dir: str, cfg: M.ModelConfig, dp: int, bucket: int,
          seed: int = 0) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": dataclasses.asdict(cfg),
        "trainer": {"dp": dp, "bucket": bucket},
        "artifacts": {},
        "params": None,
    }

    params = M.init_params(cfg, seed)
    manifest["params"] = _sig(params)
    tokens = jnp.zeros((cfg.batch, cfg.seq_len + 1), jnp.int32)
    m = M.zeros_like_tree(params)
    v = M.zeros_like_tree(params)
    step = jnp.float32(1.0)

    print(f"lowering artifacts to {out_dir} "
          f"(model: {cfg.param_count()/1e6:.2f}M params, dp={dp}, bucket={bucket})")

    # Per-worker fwd+bwd — the DP trainer's compute hot path.
    lower_artifact(
        lambda p, t: M.grad_step(cfg, p, t),
        (params, tokens), "grad_step", out_dir, manifest)

    # Optimizer applied after fabric reduction.
    lower_artifact(
        M.adamw_update,
        (params, params, m, v, step), "adamw_update", out_dir, manifest)

    # Fused single-worker step (quickstart + compute-time calibration).
    lower_artifact(
        lambda p, m_, v_, s, t: M.train_step(cfg, p, m_, v_, s, t),
        (params, m, v, step, tokens), "train_step", out_dir, manifest)

    # The in-network reduction flows: [dp, bucket] -> [dp, bucket].
    flows = jnp.zeros((dp, bucket), jnp.float32)
    lower_artifact(
        lambda x: flow_reduce(x, op="mean"),
        (flows,), "flow_reduce_mean", out_dir, manifest)
    lower_artifact(
        lambda x: flow_reduce(x, op="sum"),
        (flows,), "flow_reduce_sum", out_dir, manifest)

    # Tiny smoke artifact for runtime self-tests: (x, y) -> (x @ y + 2,).
    lower_artifact(
        lambda x, y: (jnp.matmul(x, y) + 2.0,),
        (jnp.zeros((2, 2), jnp.float32), jnp.zeros((2, 2), jnp.float32)),
        "smoke", out_dir, manifest)

    # Initial values the Rust trainer starts from (so Rust needs no RNG /
    # initializer logic): raw little-endian f32 dump in manifest order.
    init_path = os.path.join(out_dir, "init_params.bin")
    with open(init_path, "wb") as f:
        for _, leaf in M.param_leaves(params):
            import numpy as np
            f.write(np.asarray(leaf, dtype=np.float32).tobytes())
    print(f"  init_params.bin: {os.path.getsize(init_path)/1e6:.2f} MB")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("  manifest.json written")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", choices=["small", "large"], default="small")
    ap.add_argument("--dp", type=int, default=4,
                    help="data-parallel width baked into flow_reduce")
    ap.add_argument("--bucket", type=int, default=1 << 18,
                    help="gradient bucket size (f32 elements)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.LARGE if args.config == "large" else M.ModelConfig()
    build(args.out_dir, cfg, args.dp, args.bucket, args.seed)


if __name__ == "__main__":
    main()
