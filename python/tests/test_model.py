"""L2 model tests: shapes, numerics, pallas-vs-reference path equivalence,
optimizer behaviour, and the flatten-order contract with the Rust runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=4,
                    d_ff=64, seq_len=16, batch=2, use_pallas=True)
CFG_REF = M.ModelConfig(**{**CFG.__dict__, "use_pallas": False})


def _tokens(cfg, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or cfg.batch
    return jnp.asarray(
        rng.integers(0, cfg.vocab, (b, cfg.seq_len + 1)), jnp.int32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


def test_forward_shape(params):
    toks = _tokens(CFG)[:, :-1]
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)


def test_loss_is_finite_scalar(params):
    loss = M.loss_fn(CFG, params, _tokens(CFG))
    assert loss.shape == ()
    assert np.isfinite(float(loss))


def test_initial_loss_near_uniform(params):
    """Random init => loss ~ ln(vocab)."""
    loss = float(M.loss_fn(CFG, params, _tokens(CFG)))
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_pallas_and_ref_paths_agree(params):
    toks = _tokens(CFG)[:, :-1]
    a = M.forward(CFG, params, toks)
    b = M.forward(CFG_REF, params, toks)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_grads_match_between_paths(params):
    toks = _tokens(CFG)
    _, ga = M.grad_step(CFG, params, toks)
    _, gb = M.grad_step(CFG_REF, params, toks)
    fa = jax.tree_util.tree_leaves(ga)
    fb = jax.tree_util.tree_leaves(gb)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(x, y, rtol=2e-3, atol=2e-3)


def test_grad_tree_matches_param_tree(params):
    _, grads = M.grad_step(CFG, params, _tokens(CFG))
    ps = jax.tree_util.tree_structure(params)
    gs = jax.tree_util.tree_structure(grads)
    assert ps == gs


def test_grads_are_nonzero(params):
    _, grads = M.grad_step(CFG, params, _tokens(CFG))
    total = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert total > 0


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    toks = _tokens(CFG)[:, :-1]
    logits_a = M.forward(CFG, params, toks)
    toks_b = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits_b = M.forward(CFG, params, toks_b)
    np.testing.assert_allclose(
        logits_a[:, :-1], logits_b[:, :-1], rtol=1e-5, atol=1e-5)


def test_adamw_moves_params(params):
    _, grads = M.grad_step(CFG, params, _tokens(CFG))
    m = M.zeros_like_tree(params)
    v = M.zeros_like_tree(params)
    p2, m2, v2 = M.adamw_update(params, grads, m, v, jnp.float32(1.0))
    moved = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert moved > 0
    assert jax.tree_util.tree_structure(p2) == jax.tree_util.tree_structure(params)


def test_train_step_reduces_loss_on_fixed_batch(params):
    toks = _tokens(CFG, seed=3)
    m = M.zeros_like_tree(params)
    v = M.zeros_like_tree(params)
    p = params
    first = None
    loss = None
    for s in range(8):
        loss, p, m, v = M.train_step(CFG, p, m, v, jnp.float32(s + 1), toks)
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.2, (first, float(loss))


def test_train_step_equals_grad_plus_update(params):
    """The fused artifact must equal the two-artifact DP path at dp=1."""
    toks = _tokens(CFG, seed=4)
    m = M.zeros_like_tree(params)
    v = M.zeros_like_tree(params)
    loss_f, p_f, m_f, v_f = M.train_step(CFG, params, m, v, jnp.float32(1.0), toks)
    loss_g, grads = M.grad_step(CFG, params, toks)
    p_u, m_u, v_u = M.adamw_update(params, grads, m, v, jnp.float32(1.0))
    assert float(loss_f) == pytest.approx(float(loss_g), rel=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(p_f), jax.tree_util.tree_leaves(p_u)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_param_count_matches_formula():
    p = M.init_params(CFG, 0)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(p))
    assert actual == CFG.param_count()


def test_param_leaves_order_is_deterministic():
    p1 = M.init_params(CFG, 0)
    p2 = M.init_params(CFG, 1)
    n1 = [n for n, _ in M.param_leaves(p1)]
    n2 = [n for n, _ in M.param_leaves(p2)]
    assert n1 == n2
    assert len(n1) == len(set(n1))


def test_init_is_seed_deterministic():
    a = M.init_params(CFG, 7)
    b = M.init_params(CFG, 7)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(x, y)


def test_flops_and_params_scale_with_layers():
    small = M.ModelConfig(n_layers=2)
    big = M.ModelConfig(n_layers=4)
    assert big.param_count() > small.param_count()
    assert big.flops_per_token_fwd() > small.flops_per_token_fwd()


def test_large_config_is_about_100m():
    assert 50e6 < M.LARGE.param_count() < 200e6
