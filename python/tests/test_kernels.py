"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles.

The hypothesis sweeps cover shape x dtype x block-size space; the directed
tests pin the cases the paper's switch actually exercises (P = 2..20 ports,
fp16/bf16 gradients, non-tile-aligned bucket tails).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_matmul as bm
from compile.kernels import flow_reduce as fr
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return {jnp.float32: 1e-5, jnp.bfloat16: 2e-2, jnp.float16: 2e-3}[dtype]


# ---------------------------------------------------------------- flow_reduce

@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 8, 16, 20])
@pytest.mark.parametrize("op", ["sum", "mean"])
def test_flow_reduce_ports(p, op):
    rng = np.random.default_rng(p)
    x = _rand(rng, (p, 257), jnp.float32)
    got = fr.flow_reduce(x, op=op)
    want = ref.flow_reduce_ref(x, op=op)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [1, 7, 2048, 2049, 4096, 5000])
def test_flow_reduce_tail_sizes(n):
    """N not a multiple of the block: padding path must be exact."""
    rng = np.random.default_rng(n)
    x = _rand(rng, (4, n), jnp.float32)
    np.testing.assert_allclose(
        fr.flow_reduce(x), ref.flow_reduce_ref(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_flow_reduce_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = _rand(rng, (8, 512), dtype)
    got = np.asarray(fr.flow_reduce(x), np.float32)
    want = np.asarray(ref.flow_reduce_ref(x), np.float32)
    np.testing.assert_allclose(got, want, rtol=_tol(dtype), atol=_tol(dtype))


def test_flow_reduce_rows_identical():
    """All-Reduce postcondition: every output port holds the same data."""
    rng = np.random.default_rng(1)
    x = _rand(rng, (5, 300), jnp.float32)
    out = np.asarray(fr.flow_reduce(x))
    for p in range(1, 5):
        np.testing.assert_array_equal(out[0], out[p])


def test_reduce_flow_matches_ref():
    rng = np.random.default_rng(2)
    x = _rand(rng, (6, 1000), jnp.float32)
    np.testing.assert_allclose(
        fr.reduce_flow(x), ref.reduce_ref(x), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        fr.reduce_flow(x, op="mean"), ref.reduce_ref(x, op="mean"),
        rtol=1e-5, atol=1e-5)


def test_flow_reduce_mean_is_sum_over_p():
    rng = np.random.default_rng(3)
    x = _rand(rng, (4, 64), jnp.float32)
    s = np.asarray(fr.flow_reduce(x, op="sum"))
    m = np.asarray(fr.flow_reduce(x, op="mean"))
    np.testing.assert_allclose(m * 4.0, s, rtol=1e-6)


def test_flow_reduce_rejects_bad_op():
    with pytest.raises(ValueError):
        fr.flow_reduce(jnp.zeros((2, 4)), op="max")


@settings(**SETTINGS)
@given(
    p=st.integers(1, 12),
    n=st.integers(1, 600),
    block=st.sampled_from([32, 128, 2048]),
    op=st.sampled_from(["sum", "mean"]),
)
def test_flow_reduce_hypothesis(p, n, block, op):
    rng = np.random.default_rng(p * 1000 + n)
    x = _rand(rng, (p, n), jnp.float32)
    got = fr.flow_reduce(x, op=op, block_n=block)
    want = ref.flow_reduce_ref(x, op=op)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(p=st.integers(1, 10), n=st.integers(1, 400))
def test_reduce_flow_hypothesis(p, n):
    rng = np.random.default_rng(p * 977 + n)
    x = _rand(rng, (p, n), jnp.float32)
    np.testing.assert_allclose(
        fr.reduce_flow(x), ref.reduce_ref(x), rtol=1e-5, atol=1e-5)


def test_flow_reduce_vmem_budget():
    """Structural perf contract (DESIGN.md §Perf): one grid step fits a
    4 MB VMEM budget at every port count the wafer uses."""
    for p in (2, 4, 8, 16, 20, 32):
        assert fr.vmem_footprint_bytes(p) <= 4 << 20


# --------------------------------------------------------------- block_matmul

@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (128, 128, 128), (130, 70, 190), (256, 1024, 256),
              (127, 129, 2), (64, 512, 64)])
def test_matmul_shapes(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = _rand(rng, (m, k), jnp.float32)
    w = _rand(rng, (k, n), jnp.float32)
    got = bm.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(5)
    x = _rand(rng, (64, 96), dtype)
    w = _rand(rng, (96, 32), dtype)
    got = np.asarray(bm.matmul(x, w), np.float32)
    want = np.asarray(ref.matmul_ref(x, w), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_matmul_grad_matches_jnp():
    """custom_vjp: gradients through the kernel equal jnp gradients."""
    rng = np.random.default_rng(6)
    x = _rand(rng, (32, 48), jnp.float32)
    w = _rand(rng, (48, 16), jnp.float32)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(bm.matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
    tile=st.sampled_from([(32, 32, 32), (128, 128, 128), (64, 128, 32)]),
)
def test_matmul_hypothesis(m, k, n, tile):
    rng = np.random.default_rng(m * 7 + k * 11 + n * 13)
    x = _rand(rng, (m, k), jnp.float32)
    w = _rand(rng, (k, n), jnp.float32)
    got = bm.matmul(x, w, *tile)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_under_jit():
    rng = np.random.default_rng(7)
    x = _rand(rng, (128, 128), jnp.float32)
    w = _rand(rng, (128, 128), jnp.float32)
    got = jax.jit(bm.matmul)(x, w)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_mxu_utilization_estimate():
    assert bm.mxu_utilization_estimate(256, 256, 256) == 1.0
    assert bm.mxu_utilization_estimate(512, 512, 512) == 1.0
    # Tile-aligned at the explicit tile size too.
    assert bm.mxu_utilization_estimate(128, 128, 128, 128, 128, 128) == 1.0
    u = bm.mxu_utilization_estimate(130, 130, 130)
    assert 0.0 < u < 1.0


def test_matmul_vmem_budget():
    assert bm.vmem_footprint_bytes() <= 4 << 20
