"""AOT pipeline tests: lowering produces loadable HLO text whose manifest
signature matches the live pytree flatten order (the Rust contract)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

TINY = M.ModelConfig(vocab=32, d_model=16, n_layers=1, n_heads=2,
                     d_ff=32, seq_len=8, batch=1)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, TINY, dp=2, bucket=64)
    return out


def _manifest(built):
    with open(os.path.join(built, "manifest.json")) as f:
        return json.load(f)


def test_all_artifact_files_exist(built):
    man = _manifest(built)
    assert set(man["artifacts"]) == {
        "grad_step", "adamw_update", "train_step",
        "flow_reduce_mean", "flow_reduce_sum", "smoke"}
    for art in man["artifacts"].values():
        path = os.path.join(built, art["file"])
        assert os.path.exists(path)
        head = open(path).read(200)
        assert "HloModule" in head, head


def test_manifest_param_order_matches_tree(built):
    man = _manifest(built)
    live = [n for n, _ in M.param_leaves(M.init_params(TINY, 0))]
    assert [p["name"] for p in man["params"]] == live


def test_manifest_shapes_match_live_params(built):
    man = _manifest(built)
    live = M.param_leaves(M.init_params(TINY, 0))
    for entry, (_, leaf) in zip(man["params"], live):
        assert tuple(entry["shape"]) == leaf.shape
        assert entry["dtype"] == "f32"


def test_grad_step_signature(built):
    man = _manifest(built)
    art = man["artifacts"]["grad_step"]
    nparams = len(man["params"])
    assert len(art["inputs"]) == nparams + 1        # params + tokens
    assert len(art["outputs"]) == nparams + 1       # loss + grads
    tok = art["inputs"][-1]
    assert tok["dtype"] == "i32"
    assert tok["shape"] == [TINY.batch, TINY.seq_len + 1]


def test_adamw_signature(built):
    man = _manifest(built)
    art = man["artifacts"]["adamw_update"]
    n = len(man["params"])
    assert len(art["inputs"]) == 4 * n + 1          # p, g, m, v, step
    assert len(art["outputs"]) == 3 * n             # p, m, v


def test_flow_reduce_signature(built):
    man = _manifest(built)
    art = man["artifacts"]["flow_reduce_mean"]
    assert art["inputs"][0]["shape"] == [2, 64]
    assert art["outputs"][0]["shape"] == [2, 64]
    assert man["trainer"] == {"dp": 2, "bucket": 64}


def test_init_params_bin_size(built):
    man = _manifest(built)
    total = sum(int(np.prod(p["shape"])) for p in man["params"])
    size = os.path.getsize(os.path.join(built, "init_params.bin"))
    assert size == 4 * total


def test_init_params_bin_roundtrip(built):
    """The binary dump must reproduce the live initial parameters."""
    raw = np.fromfile(os.path.join(built, "init_params.bin"), np.float32)
    live = M.param_leaves(M.init_params(TINY, 0))
    off = 0
    for _, leaf in live:
        n = leaf.size
        np.testing.assert_array_equal(
            raw[off:off + n], np.asarray(leaf, np.float32).ravel())
        off += n
    assert off == raw.size


def test_hlo_text_reparses_via_xla_client(built):
    """Round-trip: the emitted text must be parseable back (same check the
    Rust loader performs via HloModuleProto::from_text_file)."""
    from jax._src.lib import xla_client as xc
    path = os.path.join(built, "smoke.hlo.txt")
    # XlaComputation from HLO text via the local client API if available;
    # otherwise at minimum the text contains an entry computation.
    text = open(path).read()
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_smoke_artifact_numerics(built):
    """Execute the lowered smoke HLO through jax itself and check it equals
    x @ y + 2 — validating the text we hand to Rust is the right program."""
    man = _manifest(built)
    assert man["artifacts"]["smoke"]["outputs"][0]["shape"] == [2, 2]
    x = jnp.array([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    y = jnp.ones((2, 2), jnp.float32)
    want = np.array([[5.0, 5.0], [9.0, 9.0]], np.float32)
    got = np.asarray(jnp.matmul(x, y) + 2.0)
    np.testing.assert_array_equal(got, want)
