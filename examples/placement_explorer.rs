//! Device-placement exploration (paper Sec. III-B2, Fig. 5).
//!
//! On the rigid 2D mesh, placements trade MP vs DP vs PP congestion; on
//! FRED, the paper's MP-consecutive placement is congestion-free and
//! random placements barely hurt. This example quantifies both, and also
//! verifies switch-level routability of the placement's concurrent flows
//! (Sec. V-C).
//!
//! Run: `cargo run --release --example placement_explorer`

use fred::coordinator::config::FabricKind;
use fred::coordinator::parallelism::Strategy;
use fred::coordinator::placement::{Placement, Priority};
use fred::fabric::fred::{FredFabric, FredVariant};
use fred::fabric::mesh::Mesh2D;
use fred::util::prng::Xorshift64;

fn main() {
    println!("== placement exploration: MP(2)-DP(4)-PP(2) (Fig. 5) ==\n");
    let strategy = Strategy::new(2, 4, 2);
    let bytes = 100e6;

    for kind in [FabricKind::Baseline, FabricKind::FredD] {
        let fabric = kind.build();
        let mesh = kind.is_mesh().then(Mesh2D::paper_baseline);
        println!("--- {} ---", kind.name());

        // The three dimension-priority placements (Fig. 5's trade-off).
        let order: Vec<usize> = match &mesh {
            Some(m) => m.snake_cycle(),
            None => (0..20).collect(),
        };
        for (name, prio) in [
            ("MP>PP>DP (paper)", Priority::MpPpDp),
            ("MP>DP>PP", Priority::MpDpPp),
            ("DP>PP>MP", Priority::DpPpMp),
        ] {
            let p = Placement::by_priority(&strategy, prio, &order);
            let score = p.congestion_score(fabric.as_ref(), &strategy, bytes);
            println!("  {name:<18} congestion score {:.3} ms", score * 1e3);
        }

        // Random placements.
        let mut rng = Xorshift64::new(7);
        let mut best = f64::INFINITY;
        let mut worst: f64 = 0.0;
        let mut sum = 0.0;
        let n = 50;
        for _ in 0..n {
            let p = Placement::random(&strategy, 20, &mut rng);
            let s = p.congestion_score(fabric.as_ref(), &strategy, bytes);
            best = best.min(s);
            worst = worst.max(s);
            sum += s;
        }
        println!(
            "  {n} random placements: best {:.3} / mean {:.3} / worst {:.3} ms\n",
            best * 1e3,
            sum / n as f64 * 1e3,
            worst * 1e3
        );
    }

    // Switch-level routability under the paper placement (Sec. V-C).
    let fabric = FredFabric::paper(FredVariant::D);
    let mp_phase = vec![(vec![0usize, 1], false), (vec![2usize, 3], false)];
    let dp_phase: Vec<(Vec<usize>, bool)> =
        (0..4).map(|i| (vec![i], true)).collect();
    println!("switch-level routability on L1_0 (FRED_3, MP-consecutive placement):");
    println!("  MP phase flows route: {}", fabric.switch_flows_route(0, &mp_phase, 3).is_ok());
    println!("  DP phase flows route: {}", fabric.switch_flows_route(0, &dp_phase, 3).is_ok());
}
