//! Strategy/topology co-exploration beyond the paper wafer.
//!
//! The paper fixes one 20-NPU wafer and a handful of strategies; the
//! sweep engine crosses fabric kind × wafer shape × fleet size ×
//! MP/DP/PP factorization × workload and ranks the result. This example
//! asks two questions the paper could not:
//!
//! 1. does FRED's advantage survive scaling the wafer to 8×8 = 64 NPUs,
//!    and which strategy wins there?
//! 2. what does a *fleet* of paper wafers buy — 1..16 wafers over an
//!    off-wafer CXL fabric (DP across wafers, MP/PP within), and how
//!    sensitive is the win to the cross-wafer egress bandwidth?
//! 3. which *egress topology* should connect the wafers — ring vs CXL
//!    fat-tree at the same egress bandwidth — and which wafer span
//!    (`--span dp,pp,mp`) wins on each?
//! 4. when (if ever) does *MP across wafers* pay off — per-layer
//!    activation All-Reduces over the egress fabric are the most
//!    egress-hungry mapping, so MP-span points should close the gap on
//!    DP/PP spans only as the egress bandwidth grows fat (the crossover
//!    is computed and reported below).
//! 5. what does *overlap-aware scheduling* buy (`--overlap off,full`) —
//!    hiding the cross-wafer gradient All-Reduce behind backward compute
//!    is capped by the backward window, so the saving should peak on
//!    egress-starved operating points and vanish on fat ones.
//! 6. which *pipeline schedule* wins where (`--schedule gpipe,1f1b,zb`)
//!    — a flush schedule's bubble grows with pipeline depth at fixed
//!    microbatches, so 1F1B's advantage over GPipe must widen as stages
//!    are added, and zero-bubble must never trail 1F1B.
//! 7. which recommendations actually *fit* (`--mem rank|prune`) — GPipe
//!    holds every in-flight microbatch's activations, so at high
//!    microbatch counts it blows past the 80 GB HBM that 1F1B's
//!    depth-capped residency respects, and the memory-aware sweep must
//!    flip the recommendation.
//! 8. what does the *next* what-if cost (`--cache`) — widening one axis
//!    of an already-priced sweep should only price the delta: every
//!    previously priced point replays from the content-addressed cache,
//!    and the replayed document is byte-identical to a fresh run.
//! 9. is brute force even necessary (`fred search`) — a seeded
//!    annealing walk over the same spec list, pricing each candidate
//!    through the same evaluator, should land on the exhaustive sweep's
//!    argmin after pricing a fraction (here <= 20%) of the space.
//!
//! Run: `cargo run --release --example strategy_sweep`

use fred::coordinator::config::FabricKind;
use fred::coordinator::memory::{MemPolicy, Recompute, ZeroStage};
use fred::coordinator::parallelism::{Strategy, WaferSpan};
use fred::coordinator::pointcache::PointCache;
use fred::coordinator::search::{run_search, SearchBudget, SearchConfig};
use fred::coordinator::stagegraph::PipeSchedule;
use fred::coordinator::sweep::{
    run_sweep, run_sweep_with, InfeasibleKind, SweepConfig, SweepOptions, WaferDims,
};
use fred::coordinator::timeline::OverlapMode;
use fred::coordinator::workload;
use fred::fabric::egress::EgressTopo;
use fred::util::units::{fmt_time, GBPS};

fn main() {
    println!("== strategy/topology sweep: Transformer-17B, 5x4 vs 8x8 ==\n");
    let cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER, WaferDims { n_l1: 8, per_l1: 8 }],
        fabrics: vec![FabricKind::Baseline, FabricKind::FredA, FabricKind::FredD],
        strategies: None,
        max_strategies: 8,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let report = run_sweep(&cfg);
    print!("{}", report.render_table(16));
    if report.truncated_strategies > 0 {
        println!("({} strategies beyond the cap not shown)", report.truncated_strategies);
    }
    for (fast, slow) in [
        (FabricKind::FredD, FabricKind::Baseline),
        (FabricKind::FredD, FabricKind::FredA),
    ] {
        let (wins, cmps) = report.count_orderings(fast, slow);
        println!(
            "{} faster than {} on {wins}/{cmps} matched (workload, wafer, strategy) points",
            fast.name(),
            slow.name()
        );
    }

    // ---------------------------------------------- multi-wafer fleets
    println!("\n== multi-wafer scale-out: GPT-3 on 1..16 paper wafers ==\n");
    let fleet_cfg = SweepConfig {
        workloads: vec![workload::gpt3()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![1, 2, 4, 8, 16],
        // Sweep the egress operating point too: half vs full CXL bonding.
        xwafer_bws: vec![1152.0 * GBPS, 2304.0 * GBPS],
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 4,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let fleet = run_sweep(&fleet_cfg);
    print!("{}", fleet.render_table(12));
    // The scale-out story in one line: best per-sample time per fleet size.
    for wafers in [1usize, 2, 4, 8, 16] {
        let best = fleet
            .points
            .iter()
            .filter(|p| p.wafers == wafers)
            .filter_map(|p| p.outcome.as_ref().ok())
            .map(|m| m.per_sample)
            .fold(f64::INFINITY, f64::min);
        println!("best per-sample @ {wafers:>2} wafer(s): {}", fmt_time(best));
    }
    // ------------------------------- egress topology x wafer span
    println!(
        "\n== egress topologies: ring vs tree vs dragonfly at 2304 GB/s, dp/pp/mp span ==\n"
    );
    let topo_cfg = SweepConfig {
        workloads: vec![workload::gpt3()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![8],
        xwafer_bws: vec![2304.0 * GBPS],
        xwafer_topos: EgressTopo::all().to_vec(),
        wafer_spans: WaferSpan::all().to_vec(),
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 4,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let topo = run_sweep(&topo_cfg);
    print!("{}", topo.render_table(12));
    // Fixed egress bandwidth, so any spread below is pure topology/span.
    for t in EgressTopo::all() {
        for span in WaferSpan::all() {
            let best = topo
                .points
                .iter()
                .filter(|p| p.topo == t && p.span == span)
                .filter_map(|p| p.outcome.as_ref().ok())
                .map(|m| m.per_sample)
                .fold(f64::INFINITY, f64::min);
            println!("best per-sample @ {:>9} / span {}: {}", t.name(), span, fmt_time(best));
        }
    }
    // ------------------- MP-span crossover vs egress bandwidth
    println!(
        "\n== wafer-span crossover: dp vs pp vs mp, Transformer-17B on 4 wafers ==\n"
    );
    let bws_gbps = [64.0, 512.0, 2304.0, 16384.0, 262144.0];
    let span_cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![4],
        xwafer_bws: bws_gbps.iter().map(|b| b * GBPS).collect(),
        wafer_spans: vec![WaferSpan::Dp, WaferSpan::Pp, WaferSpan::Mp],
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 6,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let spans = run_sweep(&span_cfg);
    let best = |bw_gbps: f64, span: WaferSpan| -> f64 {
        spans
            .points
            .iter()
            .filter(|p| p.xwafer_bw == bw_gbps * GBPS && p.span == span)
            .filter_map(|p| p.outcome.as_ref().ok())
            .map(|m| m.per_sample)
            .fold(f64::INFINITY, f64::min)
    };
    let mut crossover: Option<f64> = None;
    let mut ratios: Vec<f64> = Vec::new();
    for &bw in &bws_gbps {
        let (d, p, m) = (best(bw, WaferSpan::Dp), best(bw, WaferSpan::Pp), best(bw, WaferSpan::Mp));
        let others = d.min(p);
        let winner = if m < others {
            "mp"
        } else if d <= p {
            "dp"
        } else {
            "pp"
        };
        ratios.push(m / others);
        if m < others && crossover.is_none() {
            crossover = Some(bw);
        }
        println!(
            "egress {bw:>9.0} GB/s: dp {} | pp {} | mp {}  -> winner: {winner} \
             (mp/best-other = {:.2}x)",
            fmt_time(d),
            fmt_time(p),
            fmt_time(m),
            m / others
        );
    }
    // The span story the sweep must reproduce: MP across wafers is the
    // most egress-hungry mapping, so it can only win on fat egress
    // operating points — never on the starved end — and its gap to the
    // best other span must shrink as the egress fattens.
    assert!(
        ratios[0] > 1.0,
        "MP span must lose on the narrowest egress (ratio {})",
        ratios[0]
    );
    assert!(
        ratios[ratios.len() - 1] < ratios[0],
        "MP span's relative gap must shrink with egress bandwidth ({ratios:?})"
    );
    match crossover {
        Some(bw) => println!(
            "\nMP-span crossover: MP-across-wafers first wins at {bw:.0} GB/s egress"
        ),
        None => println!(
            "\nMP-span crossover: none within {:.0}..{:.0} GB/s — per-layer egress \
             All-Reduces only pay off beyond the swept egress range (the mp/best-other \
             ratio still fell {:.1}x -> {:.2}x)",
            bws_gbps[0],
            bws_gbps[bws_gbps.len() - 1],
            ratios[0],
            ratios[ratios.len() - 1]
        ),
    }

    // -------------- overlap crossover: compute-bound vs egress-bound
    println!(
        "\n== overlap crossover: off vs full, Transformer-17B, 4 wafers (dp span) ==\n"
    );
    // The phase-timeline engine's question: *where* does hiding the
    // cross-wafer gradient All-Reduce behind backward compute pay? The
    // hidden time is capped by the backward window, so the absolute
    // saving grows as the egress starves (comm dominates) and vanishes
    // when the egress is so fat the All-Reduce was never exposed.
    let ov_bws_gbps = [512.0, 2304.0, 262144.0];
    let ov_cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![4],
        xwafer_bws: ov_bws_gbps.iter().map(|b| b * GBPS).collect(),
        overlaps: vec![OverlapMode::Off, OverlapMode::Full],
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 6,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let ov = run_sweep(&ov_cfg);
    let best_ov = |bw_gbps: f64, mode: OverlapMode| -> f64 {
        ov.points
            .iter()
            .filter(|p| p.xwafer_bw == bw_gbps * GBPS && p.overlap == mode)
            .filter_map(|p| p.outcome.as_ref().ok())
            .map(|m| m.per_sample)
            .fold(f64::INFINITY, f64::min)
    };
    let mut savings: Vec<f64> = Vec::new();
    for &bw in &ov_bws_gbps {
        let off = best_ov(bw, OverlapMode::Off);
        let full = best_ov(bw, OverlapMode::Full);
        let saving = off - full;
        savings.push(saving);
        println!(
            "egress {bw:>9.0} GB/s: off {} | full {} | hidden {} ({:.1}% of off)",
            fmt_time(off),
            fmt_time(full),
            fmt_time(saving),
            100.0 * saving / off
        );
    }
    // The overlap story the sweep must reproduce: overlap never hurts,
    // and it helps most when the egress fabric is the bottleneck — the
    // starved operating point hides a full backward-window's worth of
    // comm, while on the fattest egress there is almost nothing left to
    // hide.
    assert!(savings.iter().all(|&s| s >= 0.0), "overlap must never hurt ({savings:?})");
    assert!(
        savings[0] > savings[savings.len() - 1],
        "overlap must pay most on the starved egress ({savings:?})"
    );

    // ------- schedule crossover: gpipe vs 1f1b vs zb over pipeline depth
    println!(
        "\n== schedule crossover: gpipe vs 1f1b vs zb, Transformer-17B at pp=2,4,5,10 ==\n"
    );
    // The stage-graph engine's question: how much of the flush bubble do
    // the steadier schedules claw back, and how does that scale with
    // depth? GPipe idles `p - 1` of `mb + p - 1` slots, so at fixed
    // microbatches its bubble — and therefore 1F1B's advantage — must
    // grow monotonically with the stage count; zero-bubble fills the
    // drain with weight-gradient work and can only extend the saving.
    let depths = [2usize, 4, 5, 10];
    let sched_cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER],
        // One strategy per pipeline depth, all exact 20-worker covers.
        strategies: Some(depths.iter().map(|&p| Strategy::new(1, 20 / p, p)).collect()),
        schedules: vec![PipeSchedule::GPipe, PipeSchedule::OneF1B, PipeSchedule::Zb],
        fabrics: vec![FabricKind::FredD],
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let sched = run_sweep(&sched_cfg);
    let at = |p: usize, s: PipeSchedule| -> f64 {
        sched
            .points
            .iter()
            .filter(|q| q.strategy.pp == p && q.schedule == s)
            .filter_map(|q| q.outcome.as_ref().ok())
            .map(|m| m.breakdown.total())
            .fold(f64::INFINITY, f64::min)
    };
    let mut last_adv = 0.0;
    for &p in &depths {
        let g = at(p, PipeSchedule::GPipe);
        let f = at(p, PipeSchedule::OneF1B);
        let z = at(p, PipeSchedule::Zb);
        let adv = g - f;
        println!(
            "pp={p:>2}: gpipe {} | 1f1b {} | zb {}  (1f1b saves {}, {:.1}% of gpipe)",
            fmt_time(g),
            fmt_time(f),
            fmt_time(z),
            fmt_time(adv),
            100.0 * adv / g
        );
        // The schedule story the sweep must reproduce: at fixed
        // microbatches the flush bubble deepens with the pipeline, so
        // 1F1B's absolute saving strictly grows with the stage count,
        // and zero-bubble never trails 1F1B.
        assert!(
            adv > last_adv,
            "1F1B's advantage must grow with pipeline depth (pp={p}: {adv} <= {last_adv})"
        );
        assert!(z <= f, "pp={p}: zb {z} > 1f1b {f}");
        last_adv = adv;
    }

    // -------- memory feasibility: gpipe vs 1f1b at high microbatch
    println!(
        "\n== memory feasibility: GPT-3 MP(1)-DP(10)-PP(2), 16 microbatches ==\n"
    );
    // The footprint model's question: which schedule actually *fits*?
    // GPipe holds all 16 in-flight activation sets per stage while 1F1B
    // caps residency at the pipeline depth, so under `--mem rank` GPipe
    // must surface as typed memory-infeasible (ranked below the feasible
    // point) and under `--mem prune` it must vanish from the report —
    // the memory-aware sweep flips the recommendation to 1F1B.
    let mem_cfg = SweepConfig {
        workloads: vec![workload::gpt3()],
        wafers: vec![WaferDims::PAPER],
        strategies: Some(vec![Strategy::new(1, 10, 2)]),
        microbatches: vec![16],
        schedules: vec![PipeSchedule::GPipe, PipeSchedule::OneF1B],
        mem: MemPolicy::Rank,
        fabrics: vec![FabricKind::FredD],
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let ranked = run_sweep(&mem_cfg);
    print!("{}", ranked.render_table(8));
    assert_eq!(ranked.points.len(), 2);
    let fits = &ranked.points[0];
    let over = &ranked.points[1];
    assert_eq!(fits.schedule, PipeSchedule::OneF1B);
    assert!(fits.outcome.is_ok() && fits.mem_ok, "1f1b must fit: {:.1} GB", fits.mem_gb);
    assert_eq!(over.schedule, PipeSchedule::GPipe);
    assert!(!over.mem_ok && over.mem_gb > 80.0, "gpipe must blow HBM: {:.1} GB", over.mem_gb);
    match &over.outcome {
        Err(e) => assert_eq!(e.kind, InfeasibleKind::Memory),
        Ok(_) => panic!("gpipe must be memory-infeasible under --mem rank"),
    }
    let pruned = run_sweep(&SweepConfig { mem: MemPolicy::Prune, ..mem_cfg });
    assert_eq!(pruned.points.len(), 1, "--mem prune must drop the gpipe point");
    assert_eq!(pruned.mem_pruned, 1, "exactly the gpipe point is dropped");
    assert_eq!(pruned.points[0].schedule, PipeSchedule::OneF1B);
    println!(
        "gpipe {:.1} GB/NPU (> 80 GB HBM) vs 1f1b {:.1} GB — `--mem prune` drops \
         gpipe and the recommendation flips to 1f1b",
        over.mem_gb, fits.mem_gb
    );

    // ------------- cached what-if: widening an axis prices only the delta
    println!("\n== cached what-if: widening the fleet axis prices only the delta ==\n");
    // The content-addressed cache's question: what does the *next*
    // what-if cost? Price a 2-fleet sweep into a fresh cache, then widen
    // the axis to three fleet sizes — every previously priced point
    // replays from the cache, only the new fleet size is priced, and the
    // replayed document is byte-identical to a from-scratch run of the
    // widened grid.
    let narrow_cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![1, 2],
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 4,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let mut opts = SweepOptions {
        cache: Some(PointCache::new()),
        ..SweepOptions::default()
    };
    let narrow = run_sweep_with(&narrow_cfg, &mut opts);
    assert_eq!(narrow.stats.cache_hits, 0, "a fresh cache cannot hit");
    assert_eq!(narrow.stats.priced, narrow.stats.total_specs);
    println!(
        "narrow run (fleets 1,2):   priced {:>2} of {:>2} specs — cache warmed",
        narrow.stats.priced, narrow.stats.total_specs
    );

    let wide_cfg = SweepConfig {
        wafer_counts: vec![1, 2, 4],
        ..narrow_cfg
    };
    let wide = run_sweep_with(&wide_cfg, &mut opts);
    assert_eq!(
        wide.stats.cache_hits, narrow.stats.total_specs,
        "every narrow-run point must replay from the cache"
    );
    assert_eq!(
        wide.stats.priced,
        wide.stats.total_specs - narrow.stats.total_specs,
        "only the 4-wafer delta is priced"
    );
    println!(
        "widened run (fleets 1,2,4): priced {:>2} of {:>2} specs — {} replayed from cache",
        wide.stats.priced, wide.stats.total_specs, wide.stats.cache_hits
    );
    let fresh_wide = run_sweep(&wide_cfg);
    assert_eq!(
        wide.report.to_json().render(),
        fresh_wide.to_json().render(),
        "the cache-assisted document must be byte-identical to a fresh run"
    );
    println!("cache-assisted document == fresh run, byte for byte");

    // ------------------------------------------------------------------
    // 9. search vs sweep: the argmin without pricing the space.
    //
    // The search walks the *same* spec list the sweep enumerates and
    // prices every candidate through the same evaluator, so when it
    // lands on the sweep's argmin the two points are byte-identical —
    // the only question is how much of the space it had to pay for.
    // The grid below has deliberate plateaus (ZeRO never changes the
    // price, every schedule ties at pp=1), so the optimum is a region,
    // not a needle.
    // ------------------------------------------------------------------
    println!("\n== search vs sweep: ResNet-152, 216-point grid, 20% budget ==\n");
    let space_cfg = SweepConfig {
        workloads: vec![workload::resnet152()],
        wafers: vec![WaferDims::PAPER],
        fabrics: vec![FabricKind::FredA, FabricKind::FredD],
        strategies: Some(vec![
            Strategy::new(1, 20, 1),
            Strategy::new(2, 10, 1),
            Strategy::new(4, 5, 1),
            Strategy::new(5, 4, 1),
            Strategy::new(2, 5, 2),
            Strategy::new(1, 10, 2),
        ]),
        schedules: vec![PipeSchedule::GPipe, PipeSchedule::OneF1B, PipeSchedule::Zb],
        zeros: vec![ZeroStage::Z0, ZeroStage::Z1, ZeroStage::Z2],
        recomputes: vec![Recompute::Off, Recompute::Full],
        threads: 1,
        ..SweepConfig::default()
    };
    let exhaustive = run_sweep(&space_cfg);
    let argmin = exhaustive.points[0]
        .outcome
        .as_ref()
        .expect("the exhaustive argmin must be feasible")
        .per_sample;
    let space = exhaustive.points.len();
    let budget = space / 5; // <= 20% of the grid
    let mut found = None;
    for seed in 1..=8u64 {
        let scfg = SearchConfig {
            seed,
            budget: SearchBudget::Points(budget),
            ..SearchConfig::default()
        };
        let result = run_search(&space_cfg, &scfg);
        let best = result
            .best()
            .and_then(|p| p.outcome.as_ref().ok())
            .map(|m| m.per_sample);
        println!(
            "seed {seed}: best {} after pricing {:>3} of {space} specs ({} pruned by bounds)",
            best.map(fmt_time).unwrap_or_else(|| "-".into()),
            result.priced,
            result.pruned
        );
        assert!(
            result.priced <= budget,
            "the budget caps priced points at {budget}, got {}",
            result.priced
        );
        if best == Some(argmin) {
            found = Some((seed, result.priced));
            break;
        }
    }
    let (seed, priced) = found.expect("no seed found the exhaustive argmin");
    println!(
        "seed {seed} found the exhaustive argmin ({}) pricing {priced} of {space} specs \
         ({:.0}% of the space)",
        fmt_time(argmin),
        100.0 * priced as f64 / space as f64
    );

    println!(
        "\nmachine-readable: `fred sweep --models gpt3 --wafers 1,2,4,8,16 \
         --fabrics fred-d --xwafer-bw 1152,2304 --xwafer-topo ring,tree,dragonfly \
         --span dp,pp,mp,2x2 --overlap off,full --microbatches 2,8 \
         --schedule gpipe,1f1b,zb --zero 0,1,2 --recompute off,full \
         --mem rank --json \
         --out sweep.json`; shard across machines (`--shard 0/4` ... `--shard 3/4`) \
         and recombine with `fred merge shard0.json shard1.json ... --out sweep.json`; \
         keep a `--cache points.json` warm so repeat what-ifs only price the delta, \
         and `--resume` an interrupted `--out` run instead of restarting it"
    );
}
