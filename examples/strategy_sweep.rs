//! Strategy/topology co-exploration beyond the paper wafer.
//!
//! The paper fixes one 20-NPU wafer and a handful of strategies; the
//! sweep engine crosses fabric kind × wafer shape × fleet size ×
//! MP/DP/PP factorization × workload and ranks the result. This example
//! asks two questions the paper could not:
//!
//! 1. does FRED's advantage survive scaling the wafer to 8×8 = 64 NPUs,
//!    and which strategy wins there?
//! 2. what does a *fleet* of paper wafers buy — 1..16 wafers over an
//!    off-wafer CXL fabric (DP across wafers, MP/PP within), and how
//!    sensitive is the win to the cross-wafer egress bandwidth?
//! 3. which *egress topology* should connect the wafers — ring vs CXL
//!    fat-tree at the same egress bandwidth — and does spanning the
//!    pipeline across wafers (`--span pp`) beat DP across wafers?
//!
//! Run: `cargo run --release --example strategy_sweep`

use fred::coordinator::config::FabricKind;
use fred::coordinator::parallelism::WaferSpan;
use fred::coordinator::sweep::{run_sweep, SweepConfig, WaferDims};
use fred::coordinator::workload;
use fred::fabric::egress::EgressTopo;
use fred::util::units::{fmt_time, GBPS};

fn main() {
    println!("== strategy/topology sweep: Transformer-17B, 5x4 vs 8x8 ==\n");
    let cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER, WaferDims { n_l1: 8, per_l1: 8 }],
        fabrics: vec![FabricKind::Baseline, FabricKind::FredA, FabricKind::FredD],
        strategies: None,
        max_strategies: 8,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let report = run_sweep(&cfg);
    print!("{}", report.render_table(16));
    if report.truncated_strategies > 0 {
        println!("({} strategies beyond the cap not shown)", report.truncated_strategies);
    }
    for (fast, slow) in [
        (FabricKind::FredD, FabricKind::Baseline),
        (FabricKind::FredD, FabricKind::FredA),
    ] {
        let (wins, cmps) = report.count_orderings(fast, slow);
        println!(
            "{} faster than {} on {wins}/{cmps} matched (workload, wafer, strategy) points",
            fast.name(),
            slow.name()
        );
    }

    // ---------------------------------------------- multi-wafer fleets
    println!("\n== multi-wafer scale-out: GPT-3 on 1..16 paper wafers ==\n");
    let fleet_cfg = SweepConfig {
        workloads: vec![workload::gpt3()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![1, 2, 4, 8, 16],
        // Sweep the egress operating point too: half vs full CXL bonding.
        xwafer_bws: vec![1152.0 * GBPS, 2304.0 * GBPS],
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 4,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let fleet = run_sweep(&fleet_cfg);
    print!("{}", fleet.render_table(12));
    // The scale-out story in one line: best per-sample time per fleet size.
    for wafers in [1usize, 2, 4, 8, 16] {
        let best = fleet
            .points
            .iter()
            .filter(|p| p.wafers == wafers)
            .filter_map(|p| p.outcome.as_ref().ok())
            .map(|m| m.per_sample)
            .fold(f64::INFINITY, f64::min);
        println!("best per-sample @ {wafers:>2} wafer(s): {}", fmt_time(best));
    }
    // ------------------------------- egress topology x wafer span
    println!("\n== egress topologies: ring vs tree vs dragonfly at 2304 GB/s, dp vs pp span ==\n");
    let topo_cfg = SweepConfig {
        workloads: vec![workload::gpt3()],
        wafers: vec![WaferDims::PAPER],
        wafer_counts: vec![8],
        xwafer_bws: vec![2304.0 * GBPS],
        xwafer_topos: EgressTopo::all().to_vec(),
        wafer_spans: WaferSpan::all().to_vec(),
        fabrics: vec![FabricKind::FredD],
        strategies: None,
        max_strategies: 4,
        bench_bytes: 100e6,
        ..SweepConfig::default()
    };
    let topo = run_sweep(&topo_cfg);
    print!("{}", topo.render_table(12));
    // Fixed egress bandwidth, so any spread below is pure topology/span.
    for t in EgressTopo::all() {
        for span in WaferSpan::all() {
            let best = topo
                .points
                .iter()
                .filter(|p| p.topo == t && p.span == span)
                .filter_map(|p| p.outcome.as_ref().ok())
                .map(|m| m.per_sample)
                .fold(f64::INFINITY, f64::min);
            println!("best per-sample @ {:>9} / span {}: {}", t.name(), span, fmt_time(best));
        }
    }
    println!(
        "\nmachine-readable: `fred sweep --models gpt3 --wafers 1,2,4,8,16 \
         --fabrics fred-d --xwafer-bw 1152,2304 --xwafer-topo ring,tree,dragonfly \
         --span dp,pp --json --out sweep.json`"
    );
}
