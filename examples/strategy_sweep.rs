//! Strategy/topology co-exploration beyond the paper wafer.
//!
//! The paper fixes one 20-NPU wafer and a handful of strategies; the
//! sweep engine crosses fabric kind × wafer shape × MP/DP/PP
//! factorization × workload and ranks the result. This example asks the
//! question the paper could not: does FRED's advantage survive scaling
//! the wafer to 8×8 = 64 NPUs, and which strategy wins there?
//!
//! Run: `cargo run --release --example strategy_sweep`

use fred::coordinator::config::FabricKind;
use fred::coordinator::sweep::{run_sweep, SweepConfig, WaferDims};
use fred::coordinator::workload;

fn main() {
    println!("== strategy/topology sweep: Transformer-17B, 5x4 vs 8x8 ==\n");
    let cfg = SweepConfig {
        workloads: vec![workload::transformer_17b()],
        wafers: vec![WaferDims::PAPER, WaferDims { n_l1: 8, per_l1: 8 }],
        fabrics: vec![FabricKind::Baseline, FabricKind::FredA, FabricKind::FredD],
        strategies: None,
        max_strategies: 8,
        bench_bytes: 100e6,
    };
    let report = run_sweep(&cfg);
    print!("{}", report.render_table(16));
    if report.truncated_strategies > 0 {
        println!("({} strategies beyond the cap not shown)", report.truncated_strategies);
    }
    for (fast, slow) in [
        (FabricKind::FredD, FabricKind::Baseline),
        (FabricKind::FredD, FabricKind::FredA),
    ] {
        let (wins, cmps) = report.count_orderings(fast, slow);
        println!(
            "{} faster than {} on {wins}/{cmps} matched (workload, wafer, strategy) points",
            fast.name(),
            slow.name()
        );
    }
    println!("\nmachine-readable: `fred sweep --models t17b --wafers 5x4,8x8 --json`");
}
