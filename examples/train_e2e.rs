//! End-to-end validation driver (DESIGN.md §6): train a real transformer
//! under data parallelism where
//!
//! * per-worker fwd+bwd is the AOT `grad_step` artifact (JAX + the Pallas
//!   `block_matmul` kernel) executed via PJRT,
//! * the gradient All-Reduce is executed numerically by the
//!   `flow_reduce_mean` artifact (the FRED μSwitch dataflow) and timed by
//!   the FRED fabric model,
//! * AdamW is the `adamw_update` artifact.
//!
//! Logs the loss curve (must decrease toward the corpus floor) and the
//! simulated wafer iteration time on the baseline mesh vs FRED-D.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e -- [steps]`

use fred::coordinator::config::FabricKind;
use fred::trainer::{Trainer, TrainerConfig};
use std::path::PathBuf;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/manifest.json missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== end-to-end DP training ({steps} steps) ==");
    let cfg = TrainerConfig {
        artifacts_dir: artifacts,
        steps,
        fabric: FabricKind::FredD,
        seed: 0,
        log_every: (steps / 12).max(1),
    };
    let mut trainer = Trainer::new(cfg.clone()).expect("trainer init");
    println!(
        "model: {:.2}M params | dp={} | PJRT platform {}",
        trainer.engine().manifest().param_count() as f64 / 1e6,
        trainer.engine().manifest().dp,
        trainer.engine().platform()
    );
    let report = trainer.train().expect("training");
    report.print();

    // Loss-curve CSV for EXPERIMENTS.md.
    let csv: String = std::iter::once("step,loss\n".to_string())
        .chain(report.losses.iter().map(|(s, l)| format!("{s},{l:.6}\n")))
        .collect();
    std::fs::write("artifacts/train_loss.csv", csv).expect("write csv");
    println!("loss curve -> artifacts/train_loss.csv");

    // Simulated-iteration comparison: same numerics, different wafer.
    println!("\nsimulated wafer comm per run (gradient All-Reduce):");
    for fabric in [FabricKind::Baseline, FabricKind::FredD] {
        let mut cfg2 = cfg.clone();
        cfg2.fabric = fabric;
        cfg2.steps = 1;
        let mut t = Trainer::new(cfg2).expect("trainer");
        let r = t.train().expect("train one step");
        println!(
            "  {:<9} comm {:.3} ms/step (+ {:.3} ms compute model)",
            r.fabric,
            r.sim_comm_time * 1e3,
            r.sim_compute_time * 1e3
        );
    }

    let (first, last) = report.first_last();
    assert!(last < first, "loss must decrease: {first} -> {last}");
    println!("\nOK: loss {first:.3} -> {last:.3}");
}
