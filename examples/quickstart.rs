//! Quickstart: the public API in ~60 lines.
//!
//! Builds the paper's wafer in both fabrics, runs one wafer-wide
//! All-Reduce through each, prints the Fig. 9-style effective bandwidth,
//! and (if `make artifacts` has run) executes the AOT smoke artifact via
//! PJRT to prove the Rust↔XLA path.
//!
//! Run: `cargo run --release --example quickstart`

use fred::coordinator::config::FabricKind;
use fred::fabric::topology::CollectiveKind;
use fred::runtime::{Engine, HostTensor};
use fred::util::units::fmt_bw;

fn main() {
    println!("== FRED quickstart ==\n");

    // 1. Fabrics at the paper's Table II/IV operating points.
    let all: Vec<usize> = (0..20).collect();
    let payload = 1e9; // 1 GB per NPU
    println!("wafer-wide All-Reduce, 1 GB per NPU (Fig. 9 left):");
    for kind in FabricKind::all() {
        let fabric = kind.build();
        let plan = fabric.plan_collective(CollectiveKind::AllReduce, &all, payload);
        let t = fabric.run_plan(&plan);
        let bw = fred::fabric::collectives::endpoint_send_bytes(
            CollectiveKind::AllReduce,
            all.len(),
            payload,
        ) / t;
        println!(
            "  {:<9} {:>9.3} ms   effective NPU BW {}",
            kind.name(),
            t * 1e3,
            fmt_bw(bw)
        );
    }

    // 2. Switch-level routing: the Fig. 7(j) conflict and its m=3 fix.
    use fred::fabric::fred::{route_flows, Flow};
    let flows = vec![
        Flow::all_reduce(vec![1, 2]),
        Flow::all_reduce(vec![3, 4]),
        Flow::all_reduce(vec![5, 0]),
        Flow::all_reduce(vec![6, 7]),
    ];
    println!(
        "\nFig. 7(j) flow set on FRED_2(8): {:?}",
        route_flows(8, 2, &flows).err().map(|e| e.to_string())
    );
    println!("same flows on FRED_3(8):        routed = {}", route_flows(8, 3, &flows).is_ok());

    // 3. The AOT/PJRT path (needs `make artifacts`).
    match Engine::new(std::path::Path::new("artifacts")) {
        Ok(mut eng) => {
            println!("\nPJRT platform: {}", eng.platform());
            let smoke = eng.artifact("smoke").expect("compile smoke artifact");
            let x = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
            let y = HostTensor::F32(vec![1.0; 4], vec![2, 2]);
            let out = smoke.run(&[x, y]).expect("execute");
            println!("smoke artifact: x@y+2 = {:?} (expect [5,5,9,9])", out[0].as_f32().unwrap());
        }
        Err(e) => println!("\n(artifacts not built; skipping PJRT demo: {e})"),
    }
}
