//! Weight streaming on the wafer (paper Sec. III-A, Fig. 4, Sec. VIII).
//!
//! Reproduces the I/O analysis end to end: the mesh's (2N−1)·P hotspot
//! derates its channels to 0.65× line rate, while FRED streams at full
//! rate — then shows what that does to GPT-3 and Transformer-1T
//! iterations.
//!
//! Run: `cargo run --release --example weight_streaming`

use fred::coordinator::config::FabricKind;
use fred::coordinator::metrics::CommType;
use fred::coordinator::sim::Simulator;
use fred::coordinator::workload;
use fred::fabric::mesh::Mesh2D;
use fred::fabric::topology::IoDirection;

fn main() {
    println!("== weight streaming on the wafer ==\n");

    // Fig. 4: channel-load analysis.
    let mesh = Mesh2D::paper_baseline();
    let (max_load, _) = mesh.channel_load_analysis();
    println!(
        "mesh {}x{}: hotspot link carries {} concurrent streams (2N-1 = {})",
        mesh.rows(),
        mesh.cols(),
        max_load,
        2 * mesh.rows() - 1
    );
    println!(
        "=> effective I/O line rate: {:.1}% (paper: 750/1152 = 65%)\n",
        100.0 * mesh.io_line_rate_factor()
    );

    // Raw stream of one GPT-3 layer-pair (7.25 GB) on each fabric.
    let all: Vec<usize> = (0..20).collect();
    let bytes = 7.25e9;
    println!("streaming a 7.25 GB layer group (GPT-3, PP=2):");
    for kind in [FabricKind::Baseline, FabricKind::FredC, FabricKind::FredD] {
        let f = kind.build();
        let t_in = f.run_plan(&f.plan_io_stream(IoDirection::Broadcast, bytes, &all));
        let t_out = f.run_plan(&f.plan_io_stream(IoDirection::ReduceOut, bytes, &all));
        println!(
            "  {:<9} weights in {:>7.2} ms | gradients out {:>7.2} ms",
            kind.name(),
            t_in * 1e3,
            t_out * 1e3
        );
    }

    // End-to-end: the two weight-streaming workloads.
    for w in [workload::gpt3(), workload::transformer_1t()] {
        println!("\n{} ({}):", w.name, w.default_strategy);
        let mut base = None;
        for kind in [FabricKind::Baseline, FabricKind::FredC, FabricKind::FredD] {
            let sim = Simulator::new(kind, w.clone(), w.default_strategy);
            let b = sim.iterate();
            let total = b.total();
            let speedup = base.get_or_insert(total).max(0.0) / total;
            println!(
                "  {:<9} total {:>8.3} s | stream exposed {:>8.3} s | speedup {speedup:.2}x",
                kind.name(),
                total,
                b.get(CommType::Stream),
            );
        }
    }
    println!("\npaper Fig. 10: GPT-3 1.34x, Transformer-1T 1.4x");
}
