#!/usr/bin/env bash
# Tier-1 gate for this repo. Run from anywhere; operates on the repo root.
#
#   ./ci.sh          # build + test (+ fmt/clippy when installed)
#   CI_STRICT=1 ./ci.sh   # fail (instead of skip) when fmt/clippy missing
#
# The build/test pair is the hard tier-1 contract (ROADMAP.md); fmt and
# clippy run with -D warnings so style/lint drift can't accumulate, but
# are skipped with a notice on toolchains that don't ship the components.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo test -q =="
cargo test -q

echo "== multi-wafer sweep smoke (mixed 2x2 span riding along) =="
# The scale-out path end to end through the real binary: a 4-wafer fleet
# swept under both the plain DP span and a 2x2 mixed span, JSON to stdout
# and --out, and the two outputs must agree byte for byte.
target/release/fred sweep --wafers 4 --models resnet152 --max-strategies 6 \
    --span dp,2x2 \
    --json --out /tmp/sweep.json > /tmp/sweep.stdout.json
cmp /tmp/sweep.json /tmp/sweep.stdout.json
test -s /tmp/sweep.json
grep -q '"schema_version":8' /tmp/sweep.json
grep -q '"wafer_span":"dp"' /tmp/sweep.json
grep -q '"wafer_span":"2x2"' /tmp/sweep.json
rm -f /tmp/sweep.json /tmp/sweep.stdout.json

echo "== egress-fabric sweep smoke (tree topology, PP across wafers) =="
# The link-level egress axes end to end: a CXL fat-tree interconnect with
# pipeline stages spanning wafers, JSON to stdout and --out byte-identical.
target/release/fred sweep --wafers 4 --models resnet152 --max-strategies 4 \
    --xwafer-topo tree --span pp \
    --json --out /tmp/sweep_pp.json > /tmp/sweep_pp.stdout.json
cmp /tmp/sweep_pp.json /tmp/sweep_pp.stdout.json
grep -q '"schema_version":8' /tmp/sweep_pp.json
grep -q '"xwafer_topo":"tree"' /tmp/sweep_pp.json
grep -q '"wafer_span":"pp"' /tmp/sweep_pp.json
rm -f /tmp/sweep_pp.json /tmp/sweep_pp.stdout.json

echo "== MP-span sweep smoke (tree topology, MP across wafers) =="
# ISSUE 4's headline path: tensor-parallel groups crossing the egress
# fabric, end to end through the real binary at schema v4.
target/release/fred sweep --wafers 4 --xwafer-topo tree --span mp \
    --models resnet152 --max-strategies 4 \
    --json --out /tmp/sweep_mp.json > /tmp/sweep_mp.stdout.json
cmp /tmp/sweep_mp.json /tmp/sweep_mp.stdout.json
grep -q '"schema_version":8' /tmp/sweep_mp.json
grep -q '"wafer_span":"mp"' /tmp/sweep_mp.json
grep -q '"global_mp"' /tmp/sweep_mp.json
rm -f /tmp/sweep_mp.json /tmp/sweep_mp.stdout.json

echo "== overlap/microbatch smoke (overlap axes) =="
# ISSUE 5's headline path: the phase-timeline engine's full-overlap
# schedule and a microbatch override, end to end through the real binary.
target/release/fred sweep --wafers 2 --models t17b --max-strategies 4 \
    --overlap full --microbatches 8 \
    --json --out /tmp/sweep_ov.json > /tmp/sweep_ov.stdout.json
cmp /tmp/sweep_ov.json /tmp/sweep_ov.stdout.json
grep -q '"schema_version":8' /tmp/sweep_ov.json
grep -q '"overlap":"full"' /tmp/sweep_ov.json
grep -q '"microbatches":8' /tmp/sweep_ov.json
grep -q '"exposed_total_s"' /tmp/sweep_ov.json
rm -f /tmp/sweep_ov.json /tmp/sweep_ov.stdout.json

echo "== pipeline-schedule smoke (schema v6 stage-graph axis) =="
# ISSUE 6's headline path: 1F1B and zero-bubble schedules priced by the
# stage-graph engine on a PP-spanning fleet, end to end through the real
# binary at schema v6.
target/release/fred sweep --wafers 2 --models t17b --max-strategies 4 \
    --span pp --schedule 1f1b,zb \
    --json --out /tmp/sweep_sched.json > /tmp/sweep_sched.stdout.json
cmp /tmp/sweep_sched.json /tmp/sweep_sched.stdout.json
grep -q '"schema_version":8' /tmp/sweep_sched.json
grep -q '"schedule":"1f1b"' /tmp/sweep_sched.json
grep -q '"schedule":"zb"' /tmp/sweep_sched.json
grep -q '"vstages"' /tmp/sweep_sched.json
rm -f /tmp/sweep_sched.json /tmp/sweep_sched.stdout.json

echo "== memory smoke (--mem prune --zero 1, schema v7 fields) =="
# The memory-feasibility axes end to end through the real binary: ZeRO-1
# sharding annotated on every point, the typed infeasible reason under
# --mem rank, and the Table V T-1T default point dropped by --mem prune.
target/release/fred sweep --models t17b --max-strategies 4 \
    --mem prune --zero 1 \
    --json --out /tmp/sweep_mem.json > /tmp/sweep_mem.stdout.json
cmp /tmp/sweep_mem.json /tmp/sweep_mem.stdout.json
grep -q '"schema_version":8' /tmp/sweep_mem.json
grep -q '"zero":"1"' /tmp/sweep_mem.json
grep -q '"mem_gb"' /tmp/sweep_mem.json
grep -q '"mem_ok"' /tmp/sweep_mem.json
grep -q '"mem_pruned"' /tmp/sweep_mem.json
target/release/fred sweep --models t1t --strategies 1,20,1 --fabrics fred-d \
    --mem rank --json > /tmp/sweep_mem_rank.json
grep -q '"error_kind":"memory"' /tmp/sweep_mem_rank.json
target/release/fred sweep --models t1t --strategies 1,20,1 --fabrics fred-d \
    --mem prune --json > /tmp/sweep_mem_prune.json
grep -q '"mem_pruned":1' /tmp/sweep_mem_prune.json
rm -f /tmp/sweep_mem.json /tmp/sweep_mem.stdout.json \
    /tmp/sweep_mem_rank.json /tmp/sweep_mem_prune.json

echo "== gpipe golden gate (--schedule gpipe == the default, byte for byte) =="
# The refactor's acceptance wall: routing the default sweep through the
# stage-graph engine must not change a single byte relative to an
# explicit --schedule gpipe, at several thread counts.
GOLDEN_ARGS=(--wafers 1,2 --models resnet152,t17b --max-strategies 4 \
    --span dp,pp --json)
target/release/fred sweep "${GOLDEN_ARGS[@]}" --threads 1 > /tmp/gp_default.json
target/release/fred sweep "${GOLDEN_ARGS[@]}" --schedule gpipe --threads 1 > /tmp/gp_explicit.json
target/release/fred sweep "${GOLDEN_ARGS[@]}" --schedule gpipe --threads 4 > /tmp/gp_threaded.json
cmp /tmp/gp_default.json /tmp/gp_explicit.json
cmp /tmp/gp_default.json /tmp/gp_threaded.json
rm -f /tmp/gp_default.json /tmp/gp_explicit.json /tmp/gp_threaded.json

echo "== memory golden gate (--mem off == the default, byte for byte) =="
# The memory model's acceptance wall: the default sweep must not change a
# single byte — explicit --mem off --zero 0 --recompute off is just the
# default's spelling, at several thread counts.
target/release/fred sweep "${GOLDEN_ARGS[@]}" --threads 1 > /tmp/mem_default.json
target/release/fred sweep "${GOLDEN_ARGS[@]}" --mem off --zero 0 --recompute off \
    --threads 1 > /tmp/mem_explicit.json
target/release/fred sweep "${GOLDEN_ARGS[@]}" --mem off --zero 0 --recompute off \
    --threads 4 > /tmp/mem_threaded.json
cmp /tmp/mem_default.json /tmp/mem_explicit.json
cmp /tmp/mem_default.json /tmp/mem_threaded.json
rm -f /tmp/mem_default.json /tmp/mem_explicit.json /tmp/mem_threaded.json

echo "== merge round-trip (sweep -> split -> merge -> cmp) =="
# Shard the same grid on the fleet axis, merge the shards, and require
# byte-identity with the unsharded run (explicit --strategies so no
# truncation bookkeeping diverges between shards).
MERGE_ARGS=(--models resnet152 --strategies "1,20,1;4,5,1;2,5,2" \
    --fabrics fred-a,fred-d --overlap off,full --json)
target/release/fred sweep --wafers 1,2 "${MERGE_ARGS[@]}" > /tmp/merge_all.json
target/release/fred sweep --wafers 1 "${MERGE_ARGS[@]}" > /tmp/merge_s1.json
target/release/fred sweep --wafers 2 "${MERGE_ARGS[@]}" > /tmp/merge_s2.json
target/release/fred merge /tmp/merge_s1.json /tmp/merge_s2.json > /tmp/merge_out.json
cmp /tmp/merge_all.json /tmp/merge_out.json
# Mismatched schema versions are rejected, never silently mixed.
printf '{"points":[],"schema_version":4,"truncated_strategies":0}\n' > /tmp/merge_stale.json
if target/release/fred merge /tmp/merge_s1.json /tmp/merge_stale.json > /dev/null 2>&1; then
    echo "merge must reject mismatched schema_version" >&2
    exit 1
fi
rm -f /tmp/merge_all.json /tmp/merge_s1.json /tmp/merge_s2.json \
    /tmp/merge_out.json /tmp/merge_stale.json

echo "== sweep determinism gate (--threads 1 vs --threads 4) =="
# Byte-identity at any thread count, enforced in CI on the full span axis
# (dp, pp, mp, and a mixed 2x2 span) *and* the schedule axes (overlap
# modes x microbatch override x pipeline schedules x ZeRO x recompute
# under --mem rank) — not just in the test suite.
target/release/fred sweep --wafers 1,2,4 --models resnet152 --max-strategies 4 \
    --span dp,pp,mp,2x2 --overlap off,dp,full --microbatches 4 \
    --schedule gpipe,1f1b,zb --zero 0,2 --recompute off,full --mem rank \
    --threads 1 --json > /tmp/sweep_t1.json
target/release/fred sweep --wafers 1,2,4 --models resnet152 --max-strategies 4 \
    --span dp,pp,mp,2x2 --overlap off,dp,full --microbatches 4 \
    --schedule gpipe,1f1b,zb --zero 0,2 --recompute off,full --mem rank \
    --threads 4 --json > /tmp/sweep_t4.json
cmp /tmp/sweep_t1.json /tmp/sweep_t4.json
rm -f /tmp/sweep_t1.json /tmp/sweep_t4.json

echo "== sweep cache smoke (warm run byte-identical to cold run) =="
# The content-addressed point cache end to end: a cold run populates the
# cache file, the warm rerun answers every lookup from it, and both
# stdout documents must agree byte for byte. Reuse stats go to stderr
# only — stdout is the byte-identity surface — and a cacheless run of
# the same grid must produce the same document too.
THRU_ARGS=(--wafers 1,2 --models resnet152 --max-strategies 4 \
    --overlap off,full --json)
rm -f /tmp/sweep_cache.json
target/release/fred sweep "${THRU_ARGS[@]}" --cache /tmp/sweep_cache.json \
    > /tmp/sweep_cold.json 2> /tmp/sweep_cold.err
target/release/fred sweep "${THRU_ARGS[@]}" --cache /tmp/sweep_cache.json \
    > /tmp/sweep_warm.json 2> /tmp/sweep_warm.err
cmp /tmp/sweep_cold.json /tmp/sweep_warm.json
grep -q 'sweep cache: 0 hits' /tmp/sweep_cold.err
grep -q ' 0 misses' /tmp/sweep_warm.err
target/release/fred sweep "${THRU_ARGS[@]}" > /tmp/sweep_nocache.json
cmp /tmp/sweep_cold.json /tmp/sweep_nocache.json
rm -f /tmp/sweep_cache.json /tmp/sweep_cold.json /tmp/sweep_warm.json \
    /tmp/sweep_nocache.json /tmp/sweep_cold.err /tmp/sweep_warm.err

echo "== sweep resume smoke (complete document re-prices nothing) =="
# Resuming over the run's own complete --out document must price zero
# points and leave the document byte-identical.
rm -f /tmp/sweep_resume.json
target/release/fred sweep "${THRU_ARGS[@]}" --out /tmp/sweep_resume.json > /dev/null
cp /tmp/sweep_resume.json /tmp/sweep_resume.orig.json
target/release/fred sweep "${THRU_ARGS[@]}" --out /tmp/sweep_resume.json --resume \
    > /dev/null 2> /tmp/sweep_resume.err
cmp /tmp/sweep_resume.json /tmp/sweep_resume.orig.json
grep -q 'priced 0' /tmp/sweep_resume.err
rm -f /tmp/sweep_resume.json /tmp/sweep_resume.orig.json /tmp/sweep_resume.err

echo "== sweep shard smoke (--shard 0/2 + 1/2 -> merge == unsharded) =="
target/release/fred sweep "${THRU_ARGS[@]}" > /tmp/shard_all.json
target/release/fred sweep "${THRU_ARGS[@]}" --shard 0/2 > /tmp/shard_0.json
target/release/fred sweep "${THRU_ARGS[@]}" --shard 1/2 > /tmp/shard_1.json
target/release/fred merge /tmp/shard_0.json /tmp/shard_1.json > /tmp/shard_merged.json
cmp /tmp/shard_all.json /tmp/shard_merged.json
rm -f /tmp/shard_all.json /tmp/shard_0.json /tmp/shard_1.json /tmp/shard_merged.json

echo "== phase-cache smoke (--phase-cache off byte-identical, nonzero hit rate) =="
# The collective-time table end to end: hits replay the exact f64 a fresh
# fluid solve would produce, so the memoized default must render the same
# stdout document as --phase-cache off — at 1 worker and at 4, where the
# table is shared across the work-stealing threads. A multi-schedule
# sweep re-prices the same phases constantly, so the per-tier stderr
# stats (next to the point-cache line) must show a nonzero hit count;
# the off run must not report table stats at all.
PC_ARGS=(--wafers 1,2 --models resnet152 --max-strategies 4 \
    --span dp,pp --schedule gpipe,1f1b,zb --zero 0,1 --json)
for t in 1 4; do
    target/release/fred sweep "${PC_ARGS[@]}" --threads "$t" \
        > "/tmp/pc_on_t$t.json" 2> "/tmp/pc_on_t$t.err"
    target/release/fred sweep "${PC_ARGS[@]}" --threads "$t" --phase-cache off \
        > "/tmp/pc_off_t$t.json" 2> "/tmp/pc_off_t$t.err"
    cmp "/tmp/pc_on_t$t.json" "/tmp/pc_off_t$t.json"
    grep -q 'sweep phase-cache: ' "/tmp/pc_on_t$t.err"
    if grep -q 'sweep phase-cache: 0 hits' "/tmp/pc_on_t$t.err"; then
        echo "threads $t: multi-schedule sweep must hit the collective-time table" >&2
        exit 1
    fi
    if grep -q 'sweep phase-cache' "/tmp/pc_off_t$t.err"; then
        echo "threads $t: --phase-cache off must not report table stats" >&2
        exit 1
    fi
done
rm -f /tmp/pc_on_t1.json /tmp/pc_on_t4.json /tmp/pc_off_t1.json /tmp/pc_off_t4.json \
    /tmp/pc_on_t1.err /tmp/pc_on_t4.err /tmp/pc_off_t1.err /tmp/pc_off_t4.err

echo "== search smoke (seeded run, schema v8 envelope + search metadata) =="
# The optimizer end to end through the real binary: a seeded budgeted
# run, --out byte-identical to --json stdout, the sweep envelope plus
# the additive `search` key, and exploration counters on stderr only.
SEARCH_SPACE=(--models resnet152 --strategies "1,20,1;4,5,1;2,5,2" \
    --fabrics fred-a,fred-d --schedule gpipe,1f1b --zero 0,1,2)
target/release/fred search "${SEARCH_SPACE[@]}" --algo anneal --seed 7 \
    --budget 12 --json --out /tmp/search.json > /tmp/search.stdout.json
cmp /tmp/search.json /tmp/search.stdout.json
grep -q '"schema_version":8' /tmp/search.json
grep -q '"search":{' /tmp/search.json
grep -q '"algo":"anneal"' /tmp/search.json
grep -q '"seed":7' /tmp/search.json
grep -q '"best_trajectory"' /tmp/search.json
# Determinism per seed: the same seed reproduces the document byte for
# byte at a different thread count.
target/release/fred search "${SEARCH_SPACE[@]}" --algo anneal --seed 7 \
    --budget 12 --threads 3 --json > /tmp/search_t3.json
cmp /tmp/search.json /tmp/search_t3.json
rm -f /tmp/search.json /tmp/search.stdout.json /tmp/search_t3.json

echo "== search oracle gate (--budget full merges to the sweep, byte for byte) =="
# The correctness wall of the shared evaluation facade: pricing the
# whole space through the search pipeline and normalizing both documents
# through `fred merge` (which drops the additive `search` key) must
# reproduce the exhaustive sweep exactly.
target/release/fred sweep "${SEARCH_SPACE[@]}" --json > /tmp/oracle_sweep.json
target/release/fred search "${SEARCH_SPACE[@]}" --budget full --top 0 --json \
    > /tmp/oracle_search.json
target/release/fred merge /tmp/oracle_sweep.json > /tmp/oracle_sweep_norm.json
target/release/fred merge /tmp/oracle_search.json > /tmp/oracle_search_norm.json
cmp /tmp/oracle_sweep_norm.json /tmp/oracle_search_norm.json
# A second oracle space exercising the evolve algorithm and the memory
# axes: full-budget output is algorithm-independent by construction.
target/release/fred sweep "${SEARCH_SPACE[@]}" --mem rank --json \
    > /tmp/oracle2_sweep.json
target/release/fred search "${SEARCH_SPACE[@]}" --mem rank --algo evolve \
    --budget full --top 0 --json > /tmp/oracle2_search.json
target/release/fred merge /tmp/oracle2_sweep.json > /tmp/oracle2_sweep_norm.json
target/release/fred merge /tmp/oracle2_search.json > /tmp/oracle2_search_norm.json
cmp /tmp/oracle2_sweep_norm.json /tmp/oracle2_search_norm.json
# A budgeted walk must find the sweep's rank-1 per-sample time while
# pricing strictly less than the space (the grid has deliberate pricing
# plateaus — ZeRO never changes the price — so the argmin is a region).
# Deterministic per seed; a handful of seeds are allowed, each capped at
# half the space.
best_sweep=$(grep -o '"per_sample_s":[0-9e.+-]*' /tmp/oracle_sweep_norm.json | head -1)
found=0
for seed in 1 2 3 4 5; do
    target/release/fred search "${SEARCH_SPACE[@]}" --seed "$seed" --budget 18 \
        --json > /tmp/search_budget.json
    best_search=$(grep -o '"per_sample_s":[0-9e.+-]*' /tmp/search_budget.json | head -1)
    if [ "$best_search" = "$best_sweep" ]; then
        found=1
        break
    fi
done
if [ "$found" != "1" ]; then
    echo "budgeted search (seeds 1-5, 18 of 36 points) never found the sweep argmin" >&2
    exit 1
fi
rm -f /tmp/oracle_sweep.json /tmp/oracle_search.json /tmp/oracle_sweep_norm.json \
    /tmp/oracle_search_norm.json /tmp/oracle2_sweep.json /tmp/oracle2_search.json \
    /tmp/oracle2_sweep_norm.json /tmp/oracle2_search_norm.json /tmp/search_budget.json

echo "== search error paths (exit 2, not silence) =="
for bad in "--algo genetic" "--budget 0" "--budget many" "--seed -1" \
    "--seed x" "--top x" "--placements x" "--threads 0"; do
    # shellcheck disable=SC2086
    if target/release/fred search --models resnet152 --strategies 1,20,1 $bad \
        --json > /dev/null 2>&1; then
        echo "search $bad must exit 2" >&2
        exit 1
    fi
done

echo "== throughput-flag error paths (exit 2, not silence) =="
# Bad shard specs, --resume without --out, and unknown --phase-cache
# values must fail loudly.
for bad in "--shard 2/2" "--shard 3/2" "--shard x/2" "--shard 1/0" \
    "--shard 2" "--resume" "--phase-cache maybe"; do
    # shellcheck disable=SC2086
    if target/release/fred sweep --models resnet152 --strategies 1,20,1 $bad \
        --json > /dev/null 2>&1; then
        echo "sweep $bad must exit 2" >&2
        exit 1
    fi
done
printf '{not json' > /tmp/bad_cache.json
if target/release/fred sweep --models resnet152 --strategies 1,20,1 \
    --cache /tmp/bad_cache.json --json > /dev/null 2>&1; then
    echo "corrupt --cache must exit 2" >&2
    exit 1
fi
printf '{"points":[],"schema_version":4,"truncated_strategies":0}\n' > /tmp/stale_resume.json
if target/release/fred sweep --models resnet152 --strategies 1,20,1 \
    --resume --out /tmp/stale_resume.json --json > /dev/null 2>&1; then
    echo "stale-schema --resume must exit 2" >&2
    exit 1
fi
rm -f /tmp/bad_cache.json /tmp/stale_resume.json

echo "== perf smoke: sweep throughput vs committed baseline =="
# BENCH_sweep.json at the repo root is the committed throughput baseline;
# a fresh bench run overwrites the working copy and `fred perfgate`
# compares the two (2x regression threshold). Warn-only by default —
# shared runners are noisy — hard gate under CI_STRICT=1. With no
# committed baseline yet, the run seeds the file instead (commit it).
if [ -f BENCH_sweep.json ]; then
    cp BENCH_sweep.json /tmp/bench_sweep_baseline.json
    cargo bench --bench bench_sweep > /dev/null
    if [ "${CI_STRICT:-0}" = "1" ]; then
        target/release/fred perfgate /tmp/bench_sweep_baseline.json BENCH_sweep.json
    else
        target/release/fred perfgate /tmp/bench_sweep_baseline.json BENCH_sweep.json \
            || echo "perf smoke: WARNING - sweep throughput regressed vs baseline (CI_STRICT=1 to fail)"
    fi
    rm -f /tmp/bench_sweep_baseline.json
else
    cargo bench --bench bench_sweep > /dev/null
    echo "perf smoke: no committed BENCH_sweep.json baseline; this run seeded one - commit it"
fi

if command -v rustfmt >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
elif [ "${CI_STRICT:-0}" = "1" ]; then
    echo "rustfmt missing and CI_STRICT=1" >&2
    exit 1
else
    echo "(rustfmt not installed; skipping cargo fmt --check)"
fi

if command -v cargo-clippy >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
elif [ "${CI_STRICT:-0}" = "1" ]; then
    echo "clippy missing and CI_STRICT=1" >&2
    exit 1
else
    echo "(clippy not installed; skipping)"
fi

echo "CI OK"
